"""Pure-jnp k-mismatch oracles: shifted byte compares, no packed machinery —
an implementation-independent reference for the kernel and the engine."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.packing import as_u8, shift_left


def kmismatch_ref(text, pattern, k: int) -> jnp.ndarray:
    """bool[n]: Hamming distance of the window at i to pattern <= k."""
    t, p = as_u8(text), as_u8(pattern)
    n, m = t.shape[0], p.shape[0]
    if n < m:
        return jnp.zeros((n,), jnp.bool_)
    mm = jnp.zeros((n,), jnp.int32)
    for j in range(m):
        mm = mm + (shift_left(t, j) != p[j]).astype(jnp.int32)
    valid = jnp.arange(n) <= (n - m)
    return (mm <= k) & valid


def approx_batched_ref(texts, patterns, k: int, lengths=None) -> jnp.ndarray:
    """bool (B, P, n) oracle with per-row valid-start masking."""
    ts, ps = as_u8(texts), as_u8(patterns)
    if ts.ndim == 1:
        ts = ts[None, :]
    B, n = ts.shape
    P, m = ps.shape
    mm = jnp.zeros((B, P, n), jnp.int32)
    for j in range(m):
        mm = mm + (
            shift_left(ts, j)[:, None, :] != ps[None, :, j, None]
        ).astype(jnp.int32)
    if lengths is None:
        lengths = jnp.full((B,), n, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    valid = jnp.arange(n)[None, :] <= (lengths[:, None] - m)
    return (mm <= k) & valid[:, None, :]
