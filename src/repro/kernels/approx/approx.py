"""k-mismatch multi-pattern Pallas kernel: P same-length patterns matched
under a Hamming budget k in ONE pass, batched over B texts.

Mirrors the multipattern kernel's shape (grid (B, ntiles), halo'd text tile
staged and packed once, whole-tile pl.when branches) with the approximate
matcher's two twists (DESIGN.md §8):

  * int8 mismatch-count accumulator: per-position mismatches accumulate as
    4-agreements-per-lane-op sums (XOR packed words, count nonzero bytes),
    clamped to k+1 each step — the running value never exceeds the budget
    sentinel, so int8 is safe for any m (and even unclamped sums fit int8
    for m <= 127);

  * early exit on budget exhaustion: the relaxed fingerprint LUT gates the
    whole tile first (a candidate-free tile skips all P verifications), and
    per pattern the anchor word's mismatch count is tested before the rest
    of the window is accumulated — when every lane already exceeds k the
    remaining word/byte passes are skipped via pl.when, the kernel analogue
    of the engine's compact-then-verify.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.engine import _FP_MULT, _WORD_SALTS, _word_offsets

DEFAULT_TILE = 4096
PACK = 4


def _pat_word(pat32, j):
    return (
        pat32[j]
        | (pat32[j + 1] << 8)
        | (pat32[j + 2] << 16)
        | (pat32[j + 3] << 24)
    )


def _nonzero_bytes(x):
    """Mismatching byte lanes (0..4) of each uint32 XOR word, as int8."""
    acc = jnp.zeros(x.shape, jnp.int8)
    for s in (0, 8, 16, 24):
        acc = acc + (((x >> jnp.uint32(s)) & jnp.uint32(0xFF)) != 0).astype(
            jnp.int8
        )
    return acc


def _approx_kernel(
    cur_ref, nxt_ref, pats_ref, lut_ref, out_ref, *, n_pat: int, m: int,
    k: int, tile: int, kbits: int, use_lut: bool,
):
    full = jnp.concatenate([cur_ref[0], nxt_ref[0]])  # (2*tile,) uint8
    b32 = full.astype(jnp.uint32)
    nw = m // PACK  # strided words only: the overlap word would double-count
    words = {}
    for i in range(nw):
        o = PACK * i
        w = b32[o : o + tile]
        w = w | (b32[o + 1 : o + 1 + tile] << 8)
        w = w | (b32[o + 2 : o + 2 + tile] << 16)
        w = w | (b32[o + 3 : o + 3 + tile] << 24)
        words[o] = w

    if use_lut:
        # relaxed-LUT gate: the window fingerprint needs ALL anchor words
        # (incl. the overlapping final one) to match the engine's hash
        offsets = _word_offsets(m)
        v = jnp.zeros((tile,), jnp.uint32)
        for i, o in enumerate(offsets):
            if o in words:
                w = words[o]
            else:
                w = b32[o : o + tile]
                w = w | (b32[o + 1 : o + 1 + tile] << 8)
                w = w | (b32[o + 2 : o + 2 + tile] << 16)
                w = w | (b32[o + 3 : o + 3 + tile] << 24)
            v = v + w * jnp.uint32(int(_WORD_SALTS[i]))
        h = ((v * jnp.uint32(int(_FP_MULT))) >> jnp.uint32(32 - kbits)).astype(
            jnp.int32
        )
        cand = lut_ref[h]  # (tile,) bool
    else:
        cand = jnp.ones((tile,), jnp.bool_)

    out_ref[0, :, :] = jnp.zeros((n_pat, tile), jnp.uint8)
    cap = jnp.int8(k + 1)  # budget-exhausted sentinel; accumulator clamp

    @pl.when(cand.any())
    def _verify():
        for pi in range(n_pat):  # static unroll over the pattern set
            pat32 = pats_ref[pi, :].astype(jnp.uint32)
            if nw:
                mm0 = _nonzero_bytes(words[0] ^ _pat_word(pat32, 0))
            else:  # m < 4: no packed word; first byte seeds the accumulator
                mm0 = (full[0:tile] != pats_ref[pi, 0]).astype(jnp.int8)

            # early exit: every lane already over budget after the anchor
            # word -> the remaining accumulation for this pattern is skipped
            @pl.when((mm0 <= jnp.int8(k)).any())
            def _rest(pi=pi, pat32=pat32, mm0=mm0):
                mm = jnp.minimum(mm0, cap)
                for i in range(1, nw):
                    miss = _nonzero_bytes(words[PACK * i] ^ _pat_word(pat32, PACK * i))
                    mm = jnp.minimum(mm + miss, cap)
                tail0 = nw * PACK if nw else 1
                for j in range(tail0, m):
                    miss = (full[j : j + tile] != pats_ref[pi, j]).astype(jnp.int8)
                    mm = jnp.minimum(mm + miss, cap)
                ok = cand & (mm <= jnp.int8(k))
                out_ref[0, pi, :] = ok.astype(jnp.uint8)


def approx_pallas(
    text_padded: jnp.ndarray,  # (B, (ntiles + 1) * tile) uint8
    patterns: jnp.ndarray,     # (P, m) uint8
    lut: jnp.ndarray,          # (2^kbits,) bool relaxed fingerprint table
    *,
    k: int,
    kbits: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
    use_lut: bool = True,
) -> jnp.ndarray:
    """Batched grid (B, ntiles) -> uint8 (B, P, ntiles * tile) k-mismatch
    masks.  ``use_lut=False`` skips the fingerprint gate and counts at every
    position — required when the compiled plan carries no relaxed LUT (m < 4,
    k > 2, or a saturated expansion)."""
    n_pat, m = patterns.shape
    B = text_padded.shape[0]
    ntiles = text_padded.shape[1] // tile - 1
    kernel = functools.partial(
        _approx_kernel, n_pat=n_pat, m=m, k=k, tile=tile, kbits=kbits,
        use_lut=use_lut,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, ntiles),
        in_specs=[
            pl.BlockSpec((1, tile), lambda b, i: (b, i)),
            pl.BlockSpec((1, tile), lambda b, i: (b, i + 1)),
            pl.BlockSpec((n_pat, m), lambda b, i: (0, 0)),
            pl.BlockSpec((lut.shape[0],), lambda b, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n_pat, tile), lambda b, i: (b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((B, n_pat, ntiles * tile), jnp.uint8),
        interpret=interpret,
    )(text_padded, text_padded, patterns, lut)
