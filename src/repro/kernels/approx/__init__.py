from repro.kernels.approx.ops import approx_batched, approx_multipattern  # noqa: F401
from repro.kernels.approx.ref import approx_batched_ref, kmismatch_ref  # noqa: F401
