"""jit'd public wrappers around the batched k-mismatch Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.engine import compile_patterns_cached
from repro.core.packing import as_u8
from repro.kernels.approx.approx import DEFAULT_TILE, approx_pallas

# int8 accumulator headroom: the kernel clamps at k+1 every step, but the
# documented safety argument (DESIGN.md §8) also covers unclamped sums only
# for m <= 127 — enforce it so the contract stays honest.
MAX_M = 127


@functools.partial(
    jax.jit, static_argnames=("k", "tile", "interpret", "kbits", "use_lut")
)
def _run(texts, lengths, patterns, lut, *, k, tile, interpret, kbits, use_lut):
    B, n = texts.shape
    m = patterns.shape[1]
    ntiles = max(1, -(-n // tile))
    padded = (
        jnp.zeros((B, (ntiles + 1) * tile), jnp.uint8).at[:, :n].set(texts)
    )
    masks = approx_pallas(
        padded, patterns, lut, k=k, kbits=kbits, tile=tile,
        interpret=interpret, use_lut=use_lut,
    )
    valid = jnp.arange(n)[None, :] <= (lengths[:, None] - m)  # (B, n)
    return masks[:, :, :n].astype(jnp.bool_) & valid[:, None, :]


def approx_batched(
    texts, patterns, k: int, lengths=None, *, tile: int = DEFAULT_TILE,
    interpret: bool = True,
):
    """(B, n) texts x (P, m) same-length patterns -> bool (B, P, n) masks of
    positions matching under <= k mismatches; 1 <= m <= 127.

    `lengths` gives per-row true lengths (matches never start in padding).
    The relaxed fingerprint LUT is compiled from the pattern stack via the
    engine's plan compiler, so kernel and core share one gate; plans without
    a usable gate (m < 4, k > 2, saturated expansion) verify every tile.
    """
    t = as_u8(texts)
    if t.ndim == 1:
        t = t[None, :]
    ps = as_u8(patterns)
    if ps.ndim != 2:
        raise ValueError("patterns must be (P, m)")
    if not 1 <= ps.shape[1] <= MAX_M:
        raise ValueError(f"approx kernel requires 1 <= m <= {MAX_M}")
    if ps.shape[1] > tile:
        raise ValueError("pattern longer than tile")
    if k < 0:
        raise ValueError("mismatch budget k must be >= 0")
    B, n = t.shape
    if lengths is None:
        lengths = jnp.full((B,), n, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    if n == 0:
        return jnp.zeros((B, ps.shape[0], 0), jnp.bool_)
    plans = compile_patterns_cached(list(jax.device_get(ps)), k=int(k))
    assert len(plans) == 1 and plans[0].ids == tuple(range(ps.shape[0]))
    plan = plans[0]
    use_lut = plan.relaxed_lut is not None and int(k) <= plan.k
    lut = plan.relaxed_lut if use_lut else plan.lut_any  # dummy carrier if off
    return _run(
        t, lengths, plan.patterns, lut, k=int(k),
        tile=tile, interpret=interpret, kbits=plan.kbits, use_lut=use_lut,
    )


def approx_multipattern(
    text, patterns, k: int, *, tile: int = DEFAULT_TILE, interpret: bool = True
):
    """(P, m) pattern stack -> bool (P, n) k-mismatch match-start masks.

    Single-text convenience wrapper over the batched kernel."""
    t = as_u8(text)
    if t.ndim != 1:
        raise ValueError("text must be 1-D; use approx_batched")
    ps = as_u8(patterns)
    if ps.ndim != 2:
        raise ValueError("patterns must be (P, m)")
    if t.shape[0] == 0:
        return jnp.zeros((ps.shape[0], 0), jnp.bool_)
    return approx_batched(t[None, :], ps, k, tile=tile, interpret=interpret)[0]
