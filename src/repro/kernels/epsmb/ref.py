"""Pure-jnp oracle for the EPSMb kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.packing import as_u8, shift_left, valid_start_mask
from repro.core.packing import pack_u32, pack_word_u32, PACK


def epsmb_ref(text, pattern, *, fuse_verify: bool = True) -> jnp.ndarray:
    """Match-start mask (fuse_verify=True) or 4-gram anchor mask (False)."""
    t, p = as_u8(text), as_u8(pattern)
    n, m = t.shape[0], p.shape[0]
    if n < m:
        return jnp.zeros((n,), dtype=jnp.bool_)
    w = pack_u32(t)
    acc = w == pack_word_u32(p[:PACK])
    if fuse_verify:
        for j in range(PACK, m):
            acc = acc & (shift_left(t, j) == p[j])
        return acc & valid_start_mask(n, m)
    return acc & valid_start_mask(n, PACK)
