"""jit'd public wrapper around the EPSMb Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.packing import PACK, as_u8, shift_left, valid_start_mask
from repro.kernels.epsmb.epsmb import DEFAULT_TILE, epsmb_pallas


@functools.partial(jax.jit, static_argnames=("tile", "fuse_verify", "interpret"))
def _run(
    text: jnp.ndarray,
    pattern: jnp.ndarray,
    *,
    tile: int,
    fuse_verify: bool,
    interpret: bool,
):
    n = text.shape[0]
    m = pattern.shape[0]
    ntiles = max(1, -(-n // tile))
    padded = jnp.zeros(((ntiles + 1) * tile,), dtype=jnp.uint8).at[:n].set(text)
    mask = epsmb_pallas(
        padded, pattern, tile=tile, fuse_verify=fuse_verify, interpret=interpret
    )
    mask = mask[:n].astype(jnp.bool_)
    if not fuse_verify:
        # paper-faithful path: kernel emits 4-gram anchor candidates; verify
        # the remaining m-4 characters here (dense masked check).
        for j in range(PACK, m):
            mask = mask & (shift_left(text, j) == pattern[j])
    return mask & valid_start_mask(n, m)


def epsmb(
    text,
    pattern,
    *,
    tile: int = DEFAULT_TILE,
    fuse_verify: bool = True,
    interpret: bool = True,
):
    """Match-start mask via the tiled packed-anchor Pallas kernel (m >= 4)."""
    t, p = as_u8(text), as_u8(pattern)
    m = p.shape[0]
    if m < PACK:
        raise ValueError("epsmb requires m >= 4 (use epsma)")
    if m > tile:
        raise ValueError("pattern longer than tile")
    if t.shape[0] == 0:
        return jnp.zeros((0,), dtype=jnp.bool_)
    return _run(t, p, tile=tile, fuse_verify=fuse_verify, interpret=interpret)
