"""EPSMb Pallas kernel: packed 4-gram anchor compare for short patterns.

Paper mapping (Fig. 1 middle): SSE's wsmatch (_mm_mpsadbw_epu8) tests the
length-4 prefix of the pattern at the first 8 offsets of a 16-byte window;
wsblend stitches adjacent windows to cover the other 8 offsets.

TPU adaptation: four consecutive text bytes are packed into one int32 *lane*
(little-endian shift-or), so a single 32-bit vector compare against the packed
pattern prefix tests a 4-gram at EVERY position of the tile.  This quarters
the number of 32-bit lane-ops versus the byte-wise shifted-AND of EPSMa — the
same constant-factor the paper buys with mpsadbw.  wsblend is unnecessary:
the halo BlockSpec (same input under an (i+1,) index_map) covers all
alignments.

Verification of the remaining m-4 characters is fused into the kernel in
packed 4-byte steps (beyond-paper fusion: the paper verifies "naively"; we
verify with the same packed compare).  Set fuse_verify=False for the
paper-faithful filter-only kernel (candidates verified by the wrapper).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 4096
PACK = 4


def _pack_u32(full: jnp.ndarray, j: int, tile: int) -> jnp.ndarray:
    """int32 lanes holding the 4-gram starting at position j+i, i<tile."""
    b = full.astype(jnp.uint32)
    w = b[j : j + tile]
    w = w | (b[j + 1 : j + 1 + tile] << 8)
    w = w | (b[j + 2 : j + 2 + tile] << 16)
    w = w | (b[j + 3 : j + 3 + tile] << 24)
    return w


def _epsmb_kernel(
    cur_ref, nxt_ref, pat_ref, out_ref, *, m: int, tile: int, fuse_verify: bool
):
    full = jnp.concatenate([cur_ref[...], nxt_ref[...]])  # (2*tile,) uint8

    def pat_word(j):
        b = pat_ref[...].astype(jnp.uint32)
        return b[j] | (b[j + 1] << 8) | (b[j + 2] << 16) | (b[j + 3] << 24)

    # wsmatch analogue: one packed compare tests the 4-byte anchor everywhere
    acc = _pack_u32(full, 0, tile) == pat_word(0)
    if fuse_verify:
        j = PACK
        while j + PACK <= m:  # packed verification in 4-byte strides
            acc = acc & (_pack_u32(full, j, tile) == pat_word(j))
            j += PACK
        for jj in range(j, m):  # byte tail (m % 4 != 0)
            acc = acc & (full[jj : jj + tile] == pat_ref[jj])
    out_ref[...] = acc.astype(jnp.uint8)


def epsmb_pallas(
    text_padded: jnp.ndarray,
    pattern: jnp.ndarray,
    *,
    tile: int = DEFAULT_TILE,
    fuse_verify: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    m = pattern.shape[0]
    ntiles = text_padded.shape[0] // tile - 1
    kernel = functools.partial(
        _epsmb_kernel, m=m, tile=tile, fuse_verify=fuse_verify
    )
    return pl.pallas_call(
        kernel,
        grid=(ntiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i + 1,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ntiles * tile,), jnp.uint8),
        interpret=interpret,
    )(text_padded, text_padded, pattern)
