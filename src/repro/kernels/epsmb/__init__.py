from repro.kernels.epsmb.ops import epsmb
from repro.kernels.epsmb.ref import epsmb_ref
