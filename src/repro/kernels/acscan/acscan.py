"""Pallas transition-scan kernel for the packed Aho-Corasick fallback.

One program per tile of lanes; each program stages its (LANE_TILE, T) class
windows plus the whole flat transition table, then walks the T (static,
~SEG + max_m - 1) steps with a vectorized gather per step — the on-chip
mirror of ``core.automaton.automaton_states``'s lax.scan, emitting only the
SEG owned states per lane (the warmup prefix is consumed, not written).

The table gather is the kernel's whole inner loop, so eligibility is a
VMEM question: ``acscan_eligible`` bounds the resident bytes (table + class
windows + state registers).  On real TPU hardware the per-step gather
lowers to a dynamic vector load; interpret=True validates the logic on CPU
(tests/test_dictionary.py pins it bit-identical to the lax.scan path, which
is itself pinned to the sequential reference in ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_TILE = 256
# VMEM ceiling for the staged state (table + windows + registers); the
# megascan budget discipline (kernels/megascan/ops.py).
ACSCAN_VMEM_BUDGET = 12 << 20


def acscan_eligible(n_cells: int, T: int, lane_tile: int = LANE_TILE) -> bool:
    resident = 4 * n_cells + 4 * lane_tile * T + 8 * lane_tile
    return resident <= ACSCAN_VMEM_BUDGET


def _ac_kernel(win_ref, delta_ref, out_ref, *, T: int, seg: int, nclass: int):
    d = delta_ref[...]  # (n_states * nclass,) int32
    s = jnp.zeros((win_ref.shape[0],), jnp.int32)
    ov = T - seg
    for t in range(T):  # T is static and small (seg + max_m - 1)
        s = jnp.take(d, s * nclass + win_ref[:, t], axis=0)
        if t >= ov:
            out_ref[:, t - ov] = s


@functools.partial(
    jax.jit, static_argnames=("nclass", "seg", "lane_tile", "interpret")
)
def acscan_states(
    win: jnp.ndarray,
    delta: jnp.ndarray,
    nclass: int,
    seg: int,
    *,
    lane_tile: int = LANE_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """(L, T) int32 lane class-windows -> (L, seg) owned automaton states."""
    L, T = win.shape
    ntiles = max(1, -(-L // lane_tile))
    pad = ntiles * lane_tile - L
    win_p = jnp.pad(win, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_ac_kernel, T=T, seg=seg, nclass=nclass),
        grid=(ntiles,),
        in_specs=[
            pl.BlockSpec((lane_tile, T), lambda i: (i, 0)),
            pl.BlockSpec(delta.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((lane_tile, seg), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ntiles * lane_tile, seg), jnp.int32),
        interpret=interpret,
    )(win_p, delta)
    return out[:L]
