"""Packed Aho-Corasick transition-scan kernel (dictionary fallback path)."""

from repro.kernels.acscan.acscan import (
    ACSCAN_VMEM_BUDGET,
    LANE_TILE,
    acscan_eligible,
    acscan_states,
)

__all__ = [
    "ACSCAN_VMEM_BUDGET",
    "LANE_TILE",
    "acscan_eligible",
    "acscan_states",
]
