"""Sequential Aho-Corasick reference: the oracle for the packed scan.

``ac_states_ref`` is the textbook one-transition-per-byte scan (numpy, host
loop) — exactly the computation ``core.automaton.automaton_states`` claims
to reproduce with its overlapped parallel lanes.  tests/test_dictionary.py
pins the two bit-identical; that equality IS the proof that the
max_m-bounded warmup re-derivation reaches the true sequential state.
"""

from __future__ import annotations

import numpy as np


def ac_states_ref(text_row: np.ndarray, classes, delta, n_classes: int):
    """(n,) int32 state after consuming each byte of ONE text row."""
    cls = np.asarray(classes, np.int64)[np.asarray(text_row, np.uint8)]
    d = np.asarray(delta, np.int64).reshape(-1, n_classes)
    out = np.zeros(cls.shape[0], np.int32)
    s = 0
    for i, c in enumerate(cls):
        s = d[s, c]
        out[i] = s
    return out


def count_ref(text_row: np.ndarray, length: int, patterns) -> np.ndarray:
    """Naive per-pattern sliding-window counts over one row (oracle)."""
    t = np.asarray(text_row, np.uint8)[: int(length)]
    out = np.zeros(len(patterns), np.int64)
    for i, p in enumerate(patterns):
        if isinstance(p, (bytes, bytearray, str)):
            p = np.frombuffer(
                p.encode() if isinstance(p, str) else p, np.uint8
            )
        else:
            p = np.asarray(p, np.uint8)
        m = p.shape[0]
        if m > t.shape[0]:
            continue
        win = np.lib.stride_tricks.sliding_window_view(t, m)
        out[i] = int((win == p[None, :]).all(-1).sum())
    return out.astype(np.int32)
