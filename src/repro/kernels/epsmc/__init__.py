from repro.kernels.epsmc.ops import epsmc
from repro.kernels.epsmc.ref import epsmc_filter_ref, epsmc_ref
