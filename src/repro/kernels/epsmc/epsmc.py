"""EPSMc Pallas kernel: strided fingerprint filter for medium patterns.

Paper mapping (Fig. 1 bottom): fingerprint every inspected text block with
wscrc (_mm_crc32_u64, an 8-byte block), look the k-bit fingerprint up in a
2^k bucket table of pattern-substring offsets, and naively verify candidates.
Blocks are inspected at stride (floor(m/beta)-1)*beta so every occurrence
contains at least one inspected aligned block.

TPU adaptation:
  * crc32 -> multiplicative hash: h(block) = (block_i32 . r) & (2^k - 1).
    The (G, beta) x (beta,) int32 product is a skinny matmul — MXU food.
  * the 2^k bucket table -> dense fingerprint comparison against the
    (m - beta + 1) pattern-substring fingerprints: noff is tiny and a dense
    (G, noff) compare beats a gather on TPU.
  * candidate verification happens in-kernel via constant-index window
    gathers into the 3-tile halo'd VMEM buffer (prev|cur|next BlockSpecs).
    A match may START in the previous tile (start = block - offset), so each
    program also owns an M_PAD = m - beta wide left apron in its output row;
    the wrapper OR-combines aprons into the global mask.

On real TPU hardware the constant-index gathers would be emitted by Mosaic as
vector loads with static offsets (they are compile-time constants); the
interpret=True path validates the logic on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.epsm import EPSMC_BETA, EPSMC_KBITS, _epsmc_stride

TARGET_TILE = 4096


def plan_tile(m: int, beta: int = EPSMC_BETA, target: int = TARGET_TILE):
    """Pick a tile that is a whole number of inspected strides."""
    stride = _epsmc_stride(m, beta)
    g = max(1, round(target / stride))
    return g * stride, stride, g


def _epsmc_kernel(
    prev_ref,
    cur_ref,
    nxt_ref,
    pat_ref,
    hp_ref,
    w_ref,
    out_ref,
    *,
    n: int,
    m: int,
    beta: int,
    kbits: int,
    tile: int,
    stride: int,
    nblocks: int,
):
    local = jnp.concatenate([prev_ref[...], cur_ref[...], nxt_ref[...]])  # (3*tile,)
    g = pl.program_id(0)
    m_pad = m - beta

    # ---- inspected aligned blocks of this tile (local coords) -------------
    # indices are built with iota primitives (not captured constants) so the
    # kernel jaxpr stays self-contained
    blk = jax.lax.broadcasted_iota(jnp.int32, (nblocks, 1), 0)
    bstart = blk * stride + tile  # (G, 1)
    bidx = bstart + jax.lax.broadcasted_iota(jnp.int32, (nblocks, beta), 1)
    blocks = local[bidx]  # (G, beta)

    # ---- wscrc analogue: multiplicative hash on the MXU --------------------
    h = jnp.dot(
        blocks.astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    ) & ((1 << kbits) - 1)  # (G,)

    # ---- candidate generation: dense fingerprint comparison ---------------
    noff = hp_ref.shape[0]
    offs = jax.lax.broadcasted_iota(jnp.int32, (1, noff), 1)
    cand = h[:, None] == hp_ref[...][None, :]  # (G, noff)
    lstart = bstart - offs  # (G, noff)
    gstart = (g * tile) + (lstart - tile)  # global starts
    cand = cand & (gstart >= 0) & (gstart <= n - m)

    out_ref[0, :] = jnp.zeros((tile + m_pad,), dtype=jnp.uint8)

    # per-tile early-out: a candidate-free tile (the common case at density
    # ~noff/2^k) skips verification entirely — the hardware analogue of the
    # block-compaction in the pure-JAX path (whole-tile branch, no per-lane
    # divergence)
    @pl.when(cand.any())
    def _verify():
        # ---- verification: halo'd window gathers ----------------------------
        widx = lstart[:, :, None] + jax.lax.broadcasted_iota(
            jnp.int32, (nblocks, noff, m), 2
        )
        windows = local[widx]  # (G, noff, m)
        ok = cand & jnp.all(windows == pat_ref[...][None, None, :], axis=-1)

        # ---- scatter into the aproned output row ----------------------------
        out_idx = lstart - (tile - m_pad)  # in [0, tile+m_pad)
        row = jnp.zeros((tile + m_pad,), dtype=jnp.uint8)
        row = row.at[out_idx.reshape(-1)].max(ok.reshape(-1).astype(jnp.uint8))
        out_ref[0, :] = row


def epsmc_pallas(
    text_padded: jnp.ndarray,
    pattern: jnp.ndarray,
    hp: jnp.ndarray,
    weights: jnp.ndarray,
    *,
    n: int,
    beta: int = EPSMC_BETA,
    kbits: int = EPSMC_KBITS,
    tile: int,
    stride: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw pallas_call.

    text_padded layout: [tile zeros | text padded to ntiles*tile | tile zeros],
    i.e. length (ntiles + 2) * tile.  Returns (ntiles, tile + m - beta) rows.
    """
    m = pattern.shape[0]
    ntiles = text_padded.shape[0] // tile - 2
    nblocks = tile // stride
    m_pad = m - beta
    kernel = functools.partial(
        _epsmc_kernel,
        n=n,
        m=m,
        beta=beta,
        kbits=kbits,
        tile=tile,
        stride=stride,
        nblocks=nblocks,
    )
    noff = hp.shape[0]
    return pl.pallas_call(
        kernel,
        grid=(ntiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),      # prev tile
            pl.BlockSpec((tile,), lambda i: (i + 1,)),  # current tile
            pl.BlockSpec((tile,), lambda i: (i + 2,)),  # next tile
            pl.BlockSpec((m,), lambda i: (0,)),         # pattern
            pl.BlockSpec((noff,), lambda i: (0,)),      # pattern fingerprints
            pl.BlockSpec((beta,), lambda i: (0,)),      # hash weights
        ],
        out_specs=pl.BlockSpec((1, tile + m_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ntiles, tile + m_pad), jnp.uint8),
        interpret=interpret,
    )(
        text_padded,
        text_padded,
        text_padded,
        pattern,
        hp,
        weights,
    )
