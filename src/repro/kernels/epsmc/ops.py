"""jit'd public wrapper around the EPSMc Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.epsm import EPSMC_BETA, EPSMC_KBITS
from repro.core.packing import as_u8, fingerprint_weights, hash_blocks
from repro.kernels.epsmc.epsmc import epsmc_pallas, plan_tile


@functools.partial(
    jax.jit, static_argnames=("beta", "kbits", "tile", "stride", "interpret")
)
def _run(
    text: jnp.ndarray,
    pattern: jnp.ndarray,
    *,
    beta: int,
    kbits: int,
    tile: int,
    stride: int,
    interpret: bool,
):
    n = text.shape[0]
    m = pattern.shape[0]
    m_pad = m - beta
    ntiles = max(1, -(-n // tile))
    padded = (
        jnp.zeros(((ntiles + 2) * tile,), dtype=jnp.uint8)
        .at[tile : tile + n]
        .set(text)
    )

    # preprocessing: fingerprints of all pattern beta-substrings
    weights = fingerprint_weights(beta)
    offs = jnp.arange(m - beta + 1)
    pat_blocks = pattern[offs[:, None] + jnp.arange(beta)[None, :]]
    hp = hash_blocks(pat_blocks, weights, kbits)

    rows = epsmc_pallas(
        padded,
        pattern,
        hp,
        weights,
        n=n,
        beta=beta,
        kbits=kbits,
        tile=tile,
        stride=stride,
        interpret=interpret,
    )  # (ntiles, tile + m_pad)

    # combine: main spans + left aprons (matches starting in the prev tile)
    main = rows[:, m_pad:].reshape(ntiles * tile)[:n].astype(jnp.bool_)
    if m_pad == 0:
        return main
    apron = rows[:, :m_pad].astype(jnp.bool_)  # row g covers [g*tile-m_pad, g*tile)
    gidx = (
        jnp.arange(ntiles)[:, None] * tile - m_pad + jnp.arange(m_pad)[None, :]
    )
    safe = jnp.where((gidx >= 0) & (gidx < n) & apron, gidx, n)
    return main.at[safe.reshape(-1)].max(apron.reshape(-1), mode="drop")


def epsmc(
    text,
    pattern,
    *,
    beta: int = EPSMC_BETA,
    kbits: int = EPSMC_KBITS,
    interpret: bool = True,
):
    """Match-start mask via the strided fingerprint Pallas kernel (m >= 2*beta)."""
    t, p = as_u8(text), as_u8(pattern)
    m = p.shape[0]
    if m < 2 * beta:
        raise ValueError(f"epsmc kernel requires m >= {2*beta} (use epsmb)")
    if t.shape[0] < m:
        return jnp.zeros((t.shape[0],), dtype=jnp.bool_)
    tile, stride, _ = plan_tile(m, beta)
    return _run(
        t, p, beta=beta, kbits=kbits, tile=tile, stride=stride, interpret=interpret
    )
