"""Pure-jnp oracle for the EPSMc kernel: the core epsmc (itself validated
against the scalar oracle) plus a trivially-correct dense matcher."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.epsm import epsmc as epsmc_core
from repro.core.packing import as_u8, shift_left, valid_start_mask


def epsmc_ref(text, pattern) -> jnp.ndarray:
    """Dense shifted-AND ground truth (definition of exact matching)."""
    t, p = as_u8(text), as_u8(pattern)
    n, m = t.shape[0], p.shape[0]
    if n < m:
        return jnp.zeros((n,), dtype=jnp.bool_)
    acc = jnp.ones((n,), dtype=jnp.bool_)
    for j in range(m):
        acc = acc & (shift_left(t, j) == p[j])
    return acc & valid_start_mask(n, m)


def epsmc_filter_ref(text, pattern, **kw) -> jnp.ndarray:
    """The pure-JAX epsmc (same filter structure, unfused)."""
    return epsmc_core(as_u8(text), as_u8(pattern), **kw)
