"""Pallas TPU kernels for the paper's hot path: packed string matching.

The paper's entire contribution is a hand-optimized kernel (SSE packed
instructions), so this layer is the heart of the reproduction.  Each kernel
lives in its own subpackage with three files:

  * ``<name>.py`` — the pl.pallas_call kernel with explicit BlockSpec tiling.
  * ``ops.py``    — the jit'd public wrapper (padding, grid setup, combine).
  * ``ref.py``    — a pure-jnp oracle the kernel is tested against.

Kernels are written for TPU as the target (VMEM tiles, halo'd BlockSpecs,
MXU-friendly fingerprint matmuls) and validated in interpret=True mode on
CPU, which executes the kernel body in Python.
"""
