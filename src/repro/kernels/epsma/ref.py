"""Pure-jnp oracle for the EPSMa kernel: dense shifted-AND over the text."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.packing import as_u8, shift_left, valid_start_mask


def epsma_ref(text, pattern) -> jnp.ndarray:
    t, p = as_u8(text), as_u8(pattern)
    n, m = t.shape[0], p.shape[0]
    if n < m:
        return jnp.zeros((n,), dtype=jnp.bool_)
    acc = jnp.ones((n,), dtype=jnp.bool_)
    for j in range(m):
        acc = acc & (shift_left(t, j) == p[j])
    return acc & valid_start_mask(n, m)
