"""EPSMa Pallas kernel: shifted-AND packed compare for very short patterns.

Paper mapping (Fig. 1 top): on SSE, each 16-byte text block T_i is compared
against B_j = (p_j)^16 with wscmp, and the per-character equality masks are
shifted and AND-ed.  On TPU one grid program owns a TILE-byte VMEM block and
the VPU performs the broadcast equality over the whole tile at once; the
"shift" of the paper becomes a static slice into a (TILE + next-tile) halo
buffer, which also replaces the paper's explicit block-crossing checks
(lines 13-14) — the halo makes crossings just another in-tile position.

BlockSpec layout:
  text is passed twice under two BlockSpecs, (i,) and (i+1,), so each program
  sees its own tile plus the following tile (the halo).  The text is padded
  by one zero tile so the last program's halo is in bounds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 4096  # bytes per grid program; multiple of the (8,128) VREG


def _epsma_kernel(cur_ref, nxt_ref, pat_ref, out_ref, *, m: int, tile: int):
    """One program: match-start mask for `tile` consecutive positions."""
    full = jnp.concatenate([cur_ref[...], nxt_ref[...]])  # (2*tile,) uint8
    acc = jnp.ones((tile,), dtype=jnp.bool_)
    for j in range(m):  # m < 4: fully unrolled, 3 compares + 2 ANDs max
        # wscmp(T, (p_j)^alpha) << j  ==  full[j : j+tile] == p_j
        acc = acc & (full[j : j + tile] == pat_ref[j])
    out_ref[...] = acc.astype(jnp.uint8)


def epsma_pallas(
    text_padded: jnp.ndarray,
    pattern: jnp.ndarray,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw pallas_call; text_padded length must be (ntiles+1)*tile."""
    m = pattern.shape[0]
    ntiles = text_padded.shape[0] // tile - 1
    kernel = functools.partial(_epsma_kernel, m=m, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(ntiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),        # current tile
            pl.BlockSpec((tile,), lambda i: (i + 1,)),    # halo tile
            pl.BlockSpec((m,), lambda i: (0,)),           # pattern (replicated)
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ntiles * tile,), jnp.uint8),
        interpret=interpret,
    )(text_padded, text_padded, pattern)
