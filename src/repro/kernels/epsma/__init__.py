from repro.kernels.epsma.ops import epsma
from repro.kernels.epsma.ref import epsma_ref
