"""jit'd public wrapper around the EPSMa Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.packing import as_u8, valid_start_mask
from repro.kernels.epsma.epsma import DEFAULT_TILE, epsma_pallas


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _run(text: jnp.ndarray, pattern: jnp.ndarray, *, tile: int, interpret: bool):
    n = text.shape[0]
    m = pattern.shape[0]
    ntiles = max(1, -(-n // tile))  # ceil
    padded = jnp.zeros(((ntiles + 1) * tile,), dtype=jnp.uint8).at[:n].set(text)
    mask = epsma_pallas(padded, pattern, tile=tile, interpret=interpret)
    return mask[:n].astype(jnp.bool_) & valid_start_mask(n, m)


def epsma(text, pattern, *, tile: int = DEFAULT_TILE, interpret: bool = True):
    """Match-start mask via the tiled Pallas kernel."""
    t, p = as_u8(text), as_u8(pattern)
    if p.shape[0] == 0:
        raise ValueError("empty pattern")
    if p.shape[0] > tile:
        raise ValueError("pattern longer than tile")
    if t.shape[0] == 0:
        return jnp.zeros((0,), dtype=jnp.bool_)
    return _run(t, p, tile=tile, interpret=interpret)
