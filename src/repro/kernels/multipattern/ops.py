"""jit'd public wrapper around the multi-pattern Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.packing import PACK, as_u8, valid_start_mask
from repro.kernels.multipattern.multipattern import DEFAULT_TILE, multipattern_pallas


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _run(text, patterns, *, tile, interpret):
    n = text.shape[0]
    m = patterns.shape[1]
    ntiles = max(1, -(-n // tile))
    padded = jnp.zeros(((ntiles + 1) * tile,), jnp.uint8).at[:n].set(text)
    masks = multipattern_pallas(padded, patterns, tile=tile, interpret=interpret)
    return masks[:, :n].astype(jnp.bool_) & valid_start_mask(n, m)[None, :]


def multipattern(text, patterns, *, tile: int = DEFAULT_TILE, interpret: bool = True):
    """(P, m) pattern stack -> bool (P, n) match-start masks; m >= 4."""
    t = as_u8(text)
    ps = as_u8(patterns)
    if ps.ndim != 2:
        raise ValueError("patterns must be (P, m)")
    if ps.shape[1] < PACK:
        raise ValueError("multipattern kernel requires m >= 4")
    if ps.shape[1] > tile:
        raise ValueError("pattern longer than tile")
    if t.shape[0] == 0:
        return jnp.zeros((ps.shape[0], 0), jnp.bool_)
    return _run(t, ps, tile=tile, interpret=interpret)
