"""jit'd public wrappers around the batched multi-pattern Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.engine import compile_patterns_cached
from repro.core.packing import PACK, as_u8
from repro.kernels.multipattern.multipattern import DEFAULT_TILE, multipattern_pallas


@functools.partial(jax.jit, static_argnames=("tile", "interpret", "kbits", "use_lut"))
def _run(texts, lengths, patterns, lut, *, tile, interpret, kbits, use_lut):
    B, n = texts.shape
    m = patterns.shape[1]
    ntiles = max(1, -(-n // tile))
    padded = (
        jnp.zeros((B, (ntiles + 1) * tile), jnp.uint8).at[:, :n].set(texts)
    )
    masks = multipattern_pallas(
        padded, patterns, lut, kbits=kbits, tile=tile, interpret=interpret,
        use_lut=use_lut,
    )
    valid = jnp.arange(n)[None, :] <= (lengths[:, None] - m)  # (B, n)
    return masks[:, :, :n].astype(jnp.bool_) & valid[:, None, :]


def multipattern_batched(
    texts, patterns, lengths=None, *, tile: int = DEFAULT_TILE,
    interpret: bool = True,
):
    """(B, n) texts x (P, m) same-length patterns -> bool (B, P, n); m >= 4.

    `lengths` gives per-row true lengths (matches never start in padding).
    The union fingerprint LUT is compiled from the pattern stack, mirroring
    the core engine's candidate gating in-kernel.
    """
    t = as_u8(texts)
    if t.ndim == 1:
        t = t[None, :]
    ps = as_u8(patterns)
    if ps.ndim != 2:
        raise ValueError("patterns must be (P, m)")
    if ps.shape[1] < PACK:
        raise ValueError("multipattern kernel requires m >= 4")
    if ps.shape[1] > tile:
        raise ValueError("pattern longer than tile")
    B, n = t.shape
    if lengths is None:
        lengths = jnp.full((B,), n, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    if n == 0:
        return jnp.zeros((B, ps.shape[0], 0), jnp.bool_)
    # one plan group (same-length stack => row order is preserved): reuse
    # the engine's LUT compiler so kernel and core share one fingerprint.
    # Only EPSMb-regime plans key their LUT by the window fingerprint the
    # kernel computes; for m >= 16 (block-fingerprint LUT) the gate is
    # disabled and every tile verifies.
    plans = compile_patterns_cached(list(jax.device_get(ps)))
    assert len(plans) == 1 and plans[0].ids == tuple(range(ps.shape[0]))
    plan = plans[0]
    return _run(
        t, lengths, plan.patterns, plan.lut_any,
        tile=tile, interpret=interpret, kbits=plan.kbits,
        use_lut=plan.regime == "b",
    )


def multipattern(text, patterns, *, tile: int = DEFAULT_TILE, interpret: bool = True):
    """(P, m) pattern stack -> bool (P, n) match-start masks; m >= 4.

    Single-text convenience wrapper over the batched kernel (seed API).
    """
    t = as_u8(text)
    if t.ndim != 1:
        raise ValueError("text must be 1-D; use multipattern_batched")
    ps = as_u8(patterns)
    if ps.ndim != 2:
        raise ValueError("patterns must be (P, m)")
    if t.shape[0] == 0:
        return jnp.zeros((ps.shape[0], 0), jnp.bool_)
    return multipattern_batched(t[None, :], ps, tile=tile, interpret=interpret)[0]
