from repro.kernels.multipattern.ops import multipattern, multipattern_batched
from repro.kernels.multipattern.ref import multipattern_batched_ref, multipattern_ref
