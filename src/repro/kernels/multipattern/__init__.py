from repro.kernels.multipattern.ops import multipattern
from repro.kernels.multipattern.ref import multipattern_ref
