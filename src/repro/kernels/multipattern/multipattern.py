"""Multi-pattern EPSMb Pallas kernel: P same-length patterns in ONE pass.

The paper's companion work (Faro & Kulekci, SPIRE 2012 — reference [10])
extends packed matching to pattern sets.  On TPU the win is bandwidth: the
text tile is staged into VMEM and packed into int32 4-gram lanes ONCE, then
all P anchors compare against the same packed registers — P-fold reuse of
the HBM->VMEM traffic that dominates the single-pattern kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 4096
PACK = 4


def _mp_kernel(cur_ref, nxt_ref, pats_ref, out_ref, *, n_pat: int, m: int, tile: int):
    full = jnp.concatenate([cur_ref[...], nxt_ref[...]])  # (2*tile,) uint8
    b = full.astype(jnp.uint32)
    # pack the text ONCE; every pattern reuses these registers
    packs = {}
    j = 0
    while j + PACK <= m:
        w = b[j : j + tile]
        w = w | (b[j + 1 : j + 1 + tile] << 8)
        w = w | (b[j + 2 : j + 2 + tile] << 16)
        w = w | (b[j + 3 : j + 3 + tile] << 24)
        packs[j] = w
        j += PACK
    tail_start = j

    for pi in range(n_pat):  # static unroll over the pattern set
        pat = pats_ref[pi, :].astype(jnp.uint32)

        def pat_word(jj):
            return pat[jj] | (pat[jj + 1] << 8) | (pat[jj + 2] << 16) | (pat[jj + 3] << 24)

        acc = packs[0] == pat_word(0)
        jj = PACK
        while jj + PACK <= m:
            acc = acc & (packs[jj] == pat_word(jj))
            jj += PACK
        for t in range(tail_start, m):
            acc = acc & (full[t : t + tile] == pats_ref[pi, t])
        out_ref[pi, :] = acc.astype(jnp.uint8)


def multipattern_pallas(
    text_padded: jnp.ndarray,
    patterns: jnp.ndarray,  # (P, m) uint8
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    n_pat, m = patterns.shape
    ntiles = text_padded.shape[0] // tile - 1
    kernel = functools.partial(_mp_kernel, n_pat=n_pat, m=m, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(ntiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i + 1,)),
            pl.BlockSpec((n_pat, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n_pat, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_pat, ntiles * tile), jnp.uint8),
        interpret=interpret,
    )(text_padded, text_padded, patterns)
