"""Multi-pattern EPSMb Pallas kernel: P same-length patterns in ONE pass,
batched over B texts.

The paper's companion work (Faro & Kulekci, SPIRE 2012 — reference [10])
extends packed matching to pattern sets.  On TPU the win is bandwidth: the
text tile is staged into VMEM and packed into int32 4-gram lanes ONCE, then
all P anchors compare against the same packed registers — P-fold reuse of
the HBM->VMEM traffic that dominates the single-pattern kernel.

This kernel mirrors the core engine's semantics (core/engine.py, DESIGN.md
§7) at the tile level:

  * grid (B, ntiles): one program per (text row, tile) — a whole batch of
    texts is matched in one pallas_call;
  * shared-LUT fingerprint path: the tile computes the same per-position
    window fingerprint as the engine and probes the union 2^k LUT staged in
    VMEM.  A candidate-free tile (the common case at density P/2^k) skips
    anchor verification entirely — a whole-tile branch via pl.when, no
    per-lane divergence;
  * candidate tiles verify with the stacked packed anchor words, exactly the
    engine's _dense_b compare.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.engine import _word_offsets, fp_accum_word, fp_finalize

DEFAULT_TILE = 4096
PACK = 4


def _pack_words(b32, tile: int, m: int):
    """Packed u32 word starting at every in-tile position, per anchor offset.

    b32 is the (2*tile,) halo'd uint8 tile as uint32; returns {offset: (tile,)}.
    """
    words = {}
    for o in _word_offsets(m):
        w = b32[o : o + tile]
        w = w | (b32[o + 1 : o + 1 + tile] << 8)
        w = w | (b32[o + 2 : o + 2 + tile] << 16)
        w = w | (b32[o + 3 : o + 3 + tile] << 24)
        words[o] = w
    return words


def _mp_kernel(
    cur_ref, nxt_ref, pats_ref, lut_ref, out_ref, *, n_pat: int, m: int,
    tile: int, kbits: int, use_lut: bool,
):
    full = jnp.concatenate([cur_ref[0], nxt_ref[0]])  # (2*tile,) uint8
    b32 = full.astype(jnp.uint32)
    # pack the text ONCE; the fingerprint and every pattern reuse these
    words = _pack_words(b32, tile, m)
    offsets = _word_offsets(m)

    if use_lut:
        # shared-LUT fingerprint (EPSMb regime only — the window fingerprint
        # mixes the packed words through the engine's fp_accum_word /
        # fp_finalize substrate, so the tile stays keyed to the same union
        # LUT as the resident and streaming paths): one probe answers "any
        # pattern here?" for all P
        v = jnp.zeros((tile,), jnp.uint32)
        for i, o in enumerate(offsets):
            v = fp_accum_word(v, words[o], i)
        h = fp_finalize(v, kbits)
        cand = lut_ref[h]  # (tile,) bool
    else:
        cand = jnp.ones((tile,), jnp.bool_)

    out_ref[0, :, :] = jnp.zeros((n_pat, tile), jnp.uint8)

    @pl.when(cand.any())
    def _verify():
        for pi in range(n_pat):  # static unroll over the pattern set
            pat = pats_ref[pi, :].astype(jnp.uint32)

            def pat_word(jj):
                return (
                    pat[jj]
                    | (pat[jj + 1] << 8)
                    | (pat[jj + 2] << 16)
                    | (pat[jj + 3] << 24)
                )

            acc = cand
            for o in offsets:
                acc = acc & (words[o] == pat_word(o))
            out_ref[0, pi, :] = acc.astype(jnp.uint8)


def multipattern_pallas(
    text_padded: jnp.ndarray,  # (B, (ntiles + 1) * tile) uint8
    patterns: jnp.ndarray,     # (P, m) uint8
    lut: jnp.ndarray,          # (2^kbits,) bool union fingerprint table
    *,
    kbits: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
    use_lut: bool = True,
) -> jnp.ndarray:
    """Batched grid (B, ntiles) -> uint8 (B, P, ntiles * tile) masks.

    ``use_lut=False`` skips the fingerprint gate and verifies every tile —
    required for m >= 16, where the compiled plan's LUT is keyed by block
    fingerprints the kernel does not compute."""
    n_pat, m = patterns.shape
    B = text_padded.shape[0]
    ntiles = text_padded.shape[1] // tile - 1
    kernel = functools.partial(
        _mp_kernel, n_pat=n_pat, m=m, tile=tile, kbits=kbits, use_lut=use_lut
    )
    return pl.pallas_call(
        kernel,
        grid=(B, ntiles),
        in_specs=[
            pl.BlockSpec((1, tile), lambda b, i: (b, i)),
            pl.BlockSpec((1, tile), lambda b, i: (b, i + 1)),
            pl.BlockSpec((n_pat, m), lambda b, i: (0, 0)),
            pl.BlockSpec((lut.shape[0],), lambda b, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n_pat, tile), lambda b, i: (b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((B, n_pat, ntiles * tile), jnp.uint8),
        interpret=interpret,
    )(text_padded, text_padded, patterns, lut)
