"""Pure-jnp oracle: vmap of the single-pattern EPSMb reference."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import as_u8
from repro.kernels.epsmb.ref import epsmb_ref


def multipattern_ref(text, patterns) -> jnp.ndarray:
    t, ps = as_u8(text), as_u8(patterns)
    return jax.vmap(lambda p: epsmb_ref(t, p))(ps)
