"""Pure-jnp oracles: vmap of the single-pattern EPSMb reference, single-text
and batched (B texts x P patterns)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import as_u8
from repro.kernels.epsmb.ref import epsmb_ref


def multipattern_ref(text, patterns) -> jnp.ndarray:
    t, ps = as_u8(text), as_u8(patterns)
    return jax.vmap(lambda p: epsmb_ref(t, p))(ps)


def multipattern_batched_ref(texts, patterns, lengths=None) -> jnp.ndarray:
    """bool (B, P, n) oracle with per-row valid-start masking."""
    ts, ps = as_u8(texts), as_u8(patterns)
    if ts.ndim == 1:
        ts = ts[None, :]
    B, n = ts.shape
    m = ps.shape[1]
    out = jax.vmap(lambda t: multipattern_ref(t, ps))(ts)
    if lengths is None:
        return out
    lengths = jnp.asarray(lengths, jnp.int32)
    valid = jnp.arange(n)[None, :] <= (lengths[:, None] - m)
    return out & valid[:, None, :]
