"""Reference oracle for the fused megakernel.

The per-group pure-JAX engine IS the specification: one window's fused
kernel counts must equal ``count_many`` over the same plans with the seam
gate ``end_min`` — which engine.py proves equivalent to the two-pass
overlap-prefix subtraction (DESIGN.md §11).  tests/test_megascan.py pins
the kernel against this for every grid shape and regime mix.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp

from repro.core.engine import PatternPlan, build_index, count_many


def megascan_count_window_ref(
    window,
    plans: Sequence[PatternPlan],
    *,
    k: Optional[int] = None,
    prev_ov: int = 0,
) -> jnp.ndarray:
    """(P_total,) int32 — the engine's answer for one streaming window."""
    idx = build_index(jnp.asarray(window, jnp.uint8))
    return count_many(idx, plans, k=k, end_min=prev_ov)[0]
