"""Fused streaming megakernel: one Pallas dispatch answers every length
group, the k-mismatch counter, and the seam correction over one staged
text tile (DESIGN.md §11)."""

from .megascan import DEFAULT_TILE, megascan_pallas
from .ops import (
    MegaSpec,
    VMEM_BUDGET,
    build_mega_spec,
    megascan_count_window,
)
from .ref import megascan_count_window_ref

__all__ = [
    "DEFAULT_TILE",
    "MegaSpec",
    "VMEM_BUDGET",
    "build_mega_spec",
    "megascan_count_window",
    "megascan_count_window_ref",
    "megascan_pallas",
]
