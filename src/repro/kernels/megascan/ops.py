"""Megakernel dispatch layer: static group specs, VMEM budgeting, and the
host-side wrapper that turns (window, plans, k, prev_ov) into one fused
pallas_call.

``build_mega_spec`` is the compile-time half: it walks a plan set ONCE and
decides, per length group, which in-kernel matcher answers it ('a'/'b'/'c'
exact, 'x' k-mismatch) and whether the whole set fits the kernel's VMEM
budget.  Ineligible sets return None and the caller (core/stream.py) keeps
the pure-JAX fused path — the kernel never silently changes results, it is
either bit-identical or not used (tests/test_megascan.py pins the identity
against the engine oracle in ref.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import PatternPlan, _word_offsets
from repro.core.epsm import EPSMC_BETA, _epsmc_stride
from repro.core.packing import PACK, fingerprint_weights

from .megascan import DEFAULT_TILE, megascan_pallas

# VMEM ceiling for the kernel's resident state (staged halo + packed
# registers + LUTs + patterns + working tiles).  16 MiB is the canonical
# per-core VMEM size; budgeting to 12 MiB leaves headroom for Mosaic
# scratch.  Exceeding it returns spec=None -> pure-JAX fused fallback.
VMEM_BUDGET = 12 << 20

# Per-group pattern ceiling: the in-kernel verify stages the full (P, m)
# pattern matrix and walks all P rows per candidate, so dictionary-scale
# groups (DESIGN.md §14) belong on the engine's bounded CSR / automaton
# routes, not in the megakernel.  Above this, spec=None -> fused fallback.
MEGA_P_MAX = 512


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """Static per-group kernel plan (hashable: jit static argument)."""

    kind: str        # 'a' | 'b' | 'c' | 'x'
    m: int
    n_patterns: int
    kbits: int
    col: int         # first output column (plan-concatenated order)
    k: int = 0       # mismatch budget ('x' only)
    use_lut: bool = False   # 'x': relaxed-LUT gate available
    stride: int = 0  # 'c' only
    noff_used: int = 0  # 'c' only


@dataclasses.dataclass(frozen=True)
class MegaSpec:
    """Static kernel configuration for one (plans, k) combination."""

    groups: Tuple[GroupSpec, ...]
    p_total: int
    tile: int
    beta: int
    vmem_bytes: int


def _effective_k(plan: PatternPlan, k: Optional[int]) -> int:
    return plan.k if k is None else int(k)


def _group_vmem(g: GroupSpec, tile: int) -> int:
    """Resident bytes this group adds: operands + its widest working set."""
    b = g.n_patterns * g.m  # patterns
    work = 0
    if g.kind == "b":
        b += 1 << g.kbits  # union LUT (bool)
        work = 4 * tile    # candidate/verify registers
    elif g.kind == "c":
        nwords = -(-g.n_patterns // 32)
        b += (1 << g.kbits) + 4 * nwords * (1 << g.kbits)  # lut_any + bits
        nblk = tile // max(g.stride, 1) + 1
        work = nblk * (g.m + g.n_patterns) * 4  # window gather + ok matrix
    elif g.kind == "x":
        if g.use_lut:
            b += 1 << g.kbits  # relaxed LUT (bool)
        work = 5 * tile  # int8 accumulator + XOR registers
    else:  # 'a'
        work = 2 * tile
    return b + work


def build_mega_spec(
    plans: Sequence[PatternPlan],
    *,
    k: Optional[int] = None,
    tile: int = DEFAULT_TILE,
) -> Optional[MegaSpec]:
    """Static spec for the fused kernel, or None when any group is
    ineligible / the set blows the VMEM budget (DESIGN.md §11 rules):

      * EPSMc groups need stride + m <= tile so a candidate window never
        escapes the 3-tile halo (start reaches back < stride, body extends
        m past the owned block);
      * every group needs m <= tile - PACK + 1 so the packed-word slices
        stay inside the halo;
      * k > 0 groups ('x') verify with the int8 clamped accumulator; the
        relaxed-LUT gate is used only when the plan was compiled for >= k
        (the reachable set covers any smaller budget — engine semantics).
    """
    if not plans:
        return None
    groups = []
    col = 0
    beta = EPSMC_BETA
    for plan in plans:
        kk = _effective_k(plan, k)
        P, m = plan.n_patterns, plan.m
        if m > tile - PACK + 1:
            return None
        if P > MEGA_P_MAX:
            return None
        if plan.regime == "c" and kk == 0 and plan.lut_bits is None:
            # bucketed EPSMc plan: its payload is the CSR entry lists, not
            # the lut_bits bitmask the kernel's 'c' matcher consumes
            return None
        if kk > 0:
            if kk > 127:  # int8 accumulator clamp ceiling
                return None
            use_lut = (
                plan.relaxed_lut is not None and kk <= plan.k and m >= PACK
            )
            groups.append(
                GroupSpec(
                    kind="x", m=m, n_patterns=P, kbits=plan.kbits, col=col,
                    k=kk, use_lut=use_lut,
                )
            )
        elif plan.regime == "a":
            groups.append(
                GroupSpec(kind="a", m=m, n_patterns=P, kbits=0, col=col)
            )
        elif plan.regime == "b":
            groups.append(
                GroupSpec(kind="b", m=m, n_patterns=P, kbits=plan.kbits, col=col)
            )
        else:
            stride = _epsmc_stride(m, beta)
            if stride + m > tile:
                return None
            groups.append(
                GroupSpec(
                    kind="c", m=m, n_patterns=P, kbits=plan.kbits, col=col,
                    stride=stride, noff_used=min(stride, m - beta + 1),
                )
            )
        col += P
    vmem = 3 * tile + 4 * 3 * tile  # staged halo (u8) + packed view (u32)
    vmem += sum(_group_vmem(g, tile) for g in groups)
    if vmem > VMEM_BUDGET:
        return None
    return MegaSpec(
        groups=tuple(groups), p_total=col, tile=tile, beta=beta,
        vmem_bytes=vmem,
    )


def _group_operands(plans: Sequence[PatternPlan], spec: MegaSpec):
    """Flat operand tuple in the kernel's ref-consumption order."""
    ops = []
    for plan, g in zip(plans, spec.groups):
        ops.append(plan.patterns)
        if g.kind == "b":
            ops.append(plan.lut_any)
        elif g.kind == "c":
            ops.append(plan.lut_any)
            ops.append(plan.lut_bits)
        elif g.kind == "x" and g.use_lut:
            ops.append(plan.relaxed_lut)
    return tuple(ops)


def _default_interpret() -> bool:
    return jax.default_backend() == "cpu"


def megascan_count_window(
    window: jnp.ndarray,
    plans: Sequence[PatternPlan],
    spec: MegaSpec,
    *,
    length=None,
    prev_ov=0,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """(P_total,) int32 counts of one streaming window through the fused
    kernel — plan-concatenated order, bit-identical to
    ``engine.count_many(build_index(window), plans, k=k, end_min=prev_ov)``
    (ref.py; pinned by tests/test_megascan.py).

    ``length``/``prev_ov`` may be traced scalars: they ride in as a (2,)
    operand so one compiled kernel serves every chunk of a stream.
    """
    if interpret is None:
        interpret = _default_interpret()
    window = jnp.asarray(window, jnp.uint8)
    n = window.shape[0]
    if length is None:
        length = n
    tile = spec.tile
    ntiles = max(1, -(-n // tile))
    pad = ntiles * tile - n
    text_padded = jnp.pad(window, (tile, pad + tile))
    scalars = jnp.stack(
        [
            jnp.asarray(length, jnp.int32),
            jnp.asarray(prev_ov, jnp.int32),
        ]
    )
    out = megascan_pallas(
        text_padded,
        scalars,
        fingerprint_weights(spec.beta),
        _group_operands(plans, spec),
        groups=spec.groups,
        p_total=spec.p_total,
        tile=tile,
        beta=spec.beta,
        interpret=interpret,
    )
    return out.sum(axis=0, dtype=jnp.int32)
