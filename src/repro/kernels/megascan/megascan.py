"""Fused streaming megakernel: every length group, the k-mismatch counter,
and the seam correction answered over ONE staged text tile.

The paper's packed matchers win because they touch each text word once with
wide instructions; "Technology Beats Algorithms" (PAPERS.md) makes the
thesis explicit — passes over memory decide exact-matching speed.  The
engine's per-group matchers each re-read the text, so G length groups cost
G passes.  This kernel stages a text tile into VMEM once and, over that one
staged tile:

  (a) accumulates the shared FingerprintBank prefix terms (the salted
      strided-word chain of DESIGN.md §9) so every EPSMb/approx group reads
      its window fingerprint as a prefix of one running sum — the on-chip
      mirror of ``engine.FingerprintBank``;
  (b) runs every eligible EPSMb group's union-LUT gate + anchor-word
      verification in one shot (the on-chip generalization of
      ``engine._count_groups_b_shared``), extended to the m >= 16 EPSMc
      block-LUT groups via strided aligned-block fingerprints probed
      against the pattern-id payload table;
  (c) folds in the k-mismatch int8 XOR accumulator (kernels/approx) behind
      a compile-time flag (a group with mismatch budget k > 0 becomes an
      'x' group);
  (d) fuses the StreamScanner seam correction: occurrences are gated by
      ``end >= prev_ov`` inside the same dispatch, replacing the separate
      overlap-prefix subtraction pass (DESIGN.md §11 proves the two forms
      produce identical integers).

Grid (ntiles,): one program per tile of ONE streaming window (streaming
windows are single text rows).  Tiles are staged with a prev|cur|next halo
(three BlockSpecs over the same padded buffer, the kernels/epsmc idiom) so
b-group windows may run into the next tile and c-group candidate starts may
reach back into the previous one.  The window length L and the seam bound
prev_ov ride in as a (2,) int32 operand so ONE compiled kernel serves every
chunk of a stream (only the last chunk's L and the first chunk's prev_ov
differ).

Output: (ntiles, P_total) int32 partial counts, P_total columns in
plan-concatenated order; the wrapper reduces over tiles.  Counts — not
masks — keep the kernel's output O(P) per tile, matching count_many's
reduced hot path.

On real TPU hardware the constant-index slices and small gathers lower to
vector loads with static offsets; interpret=True validates the logic on CPU
(tests/test_megascan.py pins it against engine.count_many, the reference
oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.engine import _n_strided_words, _word_offsets
from repro.core.packing import FP_MULT, PACK, WORD_SALTS

DEFAULT_TILE = 4096


def _pat_word(pat32, j):
    return (
        pat32[j]
        | (pat32[j + 1] << 8)
        | (pat32[j + 2] << 16)
        | (pat32[j + 3] << 24)
    )


def _nonzero_bytes(x):
    """Mismatching byte lanes (0..4) of each uint32 XOR word, as int8."""
    acc = jnp.zeros(x.shape, jnp.int8)
    for s in (0, 8, 16, 24):
        acc = acc + (((x >> jnp.uint32(s)) & jnp.uint32(0xFF)) != 0).astype(
            jnp.int8
        )
    return acc


def _mega_kernel(*refs, tile: int, groups, p_total: int, beta: int):
    """refs = prev, cur, nxt, scal, weights, *group_operands, out.

    ``groups`` is a static tuple of GroupSpec (ops.py); each names its kind
    and how many operand refs it consumes.  All python loops unroll at trace
    time — the jaxpr is one straight-line pass over the staged tile.
    """
    prev_ref, cur_ref, nxt_ref, scal_ref, w_ref = refs[:5]
    out_ref = refs[-1]
    in_refs = refs[5:-1]

    local = jnp.concatenate([prev_ref[...], cur_ref[...], nxt_ref[...]])
    b32 = local.astype(jnp.uint32)
    L = scal_ref[0]       # true window length (<= padded ntiles * tile)
    ov = scal_ref[1]      # seam bound: keep occurrences ENDING at >= ov
    t0 = pl.program_id(0) * tile
    pos = t0 + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)

    # ---- the tile is packed ONCE; every group reuses these registers ------
    words = {}

    def word(o):
        w = words.get(o)
        if w is None:
            w = b32[tile + o : tile + o + tile]
            w = w | (b32[tile + o + 1 : tile + o + 1 + tile] << 8)
            w = w | (b32[tile + o + 2 : tile + o + 2 + tile] << 16)
            w = w | (b32[tile + o + 3 : tile + o + 3 + tile] << 24)
            words[o] = w
        return w

    # ---- shared FingerprintBank prefix chain (salt i <-> offset 4i) -------
    prefix = {0: jnp.zeros((tile,), jnp.uint32)}

    def strided_sum(nterms):
        done = max(t for t in prefix if t <= nterms)
        acc = prefix[done]
        for i in range(done, nterms):
            acc = acc + word(PACK * i) * jnp.uint32(int(WORD_SALTS[i]))
            prefix[i + 1] = acc
        return prefix[nterms]

    def window_fp(m, kbits):
        ns = _n_strided_words(m)
        v = strided_sum(ns)
        if m % PACK and m >= PACK:
            v = v + word(m - PACK) * jnp.uint32(int(WORD_SALTS[ns]))
        return (
            (v * jnp.uint32(int(FP_MULT))) >> jnp.uint32(32 - kbits)
        ).astype(jnp.int32)

    def seam_gate(starts, m):
        """The fused overlap-prefix subtraction (DESIGN.md §11): a valid
        occurrence starts in [0, L-m] AND ends at >= ov."""
        return (starts <= L - m) & (starts + (m - 1) >= ov)

    out_ref[0, :] = jnp.zeros((p_total,), jnp.int32)

    ri = 0
    for g in groups:
        m, P, col = g.m, g.n_patterns, g.col
        if g.kind == "a":
            # dense shifted byte compares — EPSMa, exact for any m < 4
            pat_ref = in_refs[ri]
            ri += 1
            gate = seam_gate(pos, m)
            sums = []
            for pi in range(P):
                acc = gate
                for j in range(m):
                    acc = acc & (
                        local[tile + j : tile + j + tile] == pat_ref[pi, j]
                    )
                sums.append(jnp.sum(acc.astype(jnp.int32)))
            out_ref[0, col : col + P] = jnp.stack(sums)

        elif g.kind == "b":
            # union-LUT gate + packed anchor-word verify (EPSMb)
            pat_ref, lut_ref = in_refs[ri], in_refs[ri + 1]
            ri += 2
            h = window_fp(m, g.kbits)
            cand = lut_ref[h] & seam_gate(pos, m)
            gwords = {o: word(o) for o in _word_offsets(m)}

            # candidate-free tile (the common case at density P/2^k): the
            # whole verification branch is skipped — no per-lane divergence
            @pl.when(cand.any())
            def _verify_b(pat_ref=pat_ref, cand=cand, gwords=gwords,
                          m=m, P=P, col=col):
                sums = []
                for pi in range(P):
                    pat32 = pat_ref[pi, :].astype(jnp.uint32)
                    acc = cand
                    for o in _word_offsets(m):
                        acc = acc & (gwords[o] == _pat_word(pat32, o))
                    sums.append(jnp.sum(acc.astype(jnp.int32)))
                out_ref[0, col : col + P] = jnp.stack(sums)

        elif g.kind == "c":
            # strided aligned-block fingerprints + pattern-id payload bits
            # (EPSMc).  Each tile owns the inspected blocks starting inside
            # it; candidate windows may START in the previous tile (start =
            # block - offset), which the halo covers.  Exactly-once: every
            # occurrence contains ONE inspected block at offset < stride
            # (the dedup block), and each block belongs to one tile.
            pat_ref = in_refs[ri]
            lutany_ref = in_refs[ri + 1]
            bits_ref = in_refs[ri + 2]
            ri += 3
            stride, noff = g.stride, g.noff_used
            nblk = tile // stride + 1
            first = (t0 + stride - 1) // stride
            bg = (
                first + jax.lax.broadcasted_iota(jnp.int32, (nblk,), 0)
            ) * stride  # global inspected-block starts
            own = bg < t0 + tile
            lb = bg - t0 + tile  # local (halo) coords
            bidx = lb[:, None] + jax.lax.broadcasted_iota(
                jnp.int32, (nblk, beta), 1
            )
            h = jnp.dot(
                local[bidx].astype(jnp.int32),
                w_ref[...].astype(jnp.int32),
                preferred_element_type=jnp.int32,
            ) & ((1 << g.kbits) - 1)  # (nblk,)
            cand = lutany_ref[h] & own
            # built with iota, not captured constants (self-contained jaxpr)
            pids = jax.lax.broadcasted_iota(jnp.int32, (P,), 0)
            shifts = (pids % 32).astype(jnp.uint32)
            wsel = pids // 32

            @pl.when(cand.any())
            def _verify_c(pat_ref=pat_ref, bits_ref=bits_ref, h=h,
                          cand=cand, bg=bg, lb=lb, shifts=shifts, wsel=wsel,
                          stride=stride, noff=noff, nblk=nblk, m=m, P=P,
                          col=col):
                bits = bits_ref[h]  # (nblk, W) uint32 payloads
                pgate = (
                    (bits[:, wsel] >> shifts[None, :]) & jnp.uint32(1)
                ) != 0  # (nblk, P): patterns that registered this fp
                acc = jnp.zeros((P,), jnp.int32)
                for j in range(noff):
                    lw = lb - j
                    ws = bg - j
                    widx = lw[:, None] + jax.lax.broadcasted_iota(
                        jnp.int32, (nblk, m), 1
                    )
                    okj = jnp.all(
                        local[widx][:, None, :] == pat_ref[...][None, :, :],
                        axis=-1,
                    )  # (nblk, P)
                    gatej = cand & (ws >= 0) & seam_gate(ws, m)
                    okj = okj & pgate & gatej[:, None]
                    acc = acc + okj.astype(jnp.int32).sum(axis=0)
                out_ref[0, col : col + P] = acc

        else:  # g.kind == "x": k-mismatch int8 accumulator (compile-time k)
            pat_ref = in_refs[ri]
            ri += 1
            gate = seam_gate(pos, m)
            if g.use_lut:
                lut_ref = in_refs[ri]
                ri += 1
                cand = lut_ref[window_fp(m, g.kbits)] & gate
            else:
                cand = gate
            nw = m // PACK  # strided words only: overlap would double-count
            sw = [word(PACK * i) for i in range(nw)]
            cap = jnp.int8(g.k + 1)  # budget-exhausted sentinel / clamp

            @pl.when(cand.any())
            def _verify_x(pat_ref=pat_ref, cand=cand, sw=sw, cap=cap,
                          nw=nw, m=m, P=P, col=col, k=g.k):
                sums = []
                for pi in range(P):
                    pat32 = pat_ref[pi, :].astype(jnp.uint32)
                    mm = jnp.zeros((tile,), jnp.int8)
                    for i in range(nw):
                        miss = _nonzero_bytes(
                            sw[i] ^ _pat_word(pat32, PACK * i)
                        )
                        mm = jnp.minimum(mm + miss, cap)
                    for j in range(nw * PACK, m):
                        miss = (
                            local[tile + j : tile + j + tile]
                            != pat_ref[pi, j]
                        ).astype(jnp.int8)
                        mm = jnp.minimum(mm + miss, cap)
                    ok = cand & (mm <= jnp.int8(k))
                    sums.append(jnp.sum(ok.astype(jnp.int32)))
                out_ref[0, col : col + P] = jnp.stack(sums)


def megascan_pallas(
    text_padded: jnp.ndarray,   # ((ntiles + 2) * tile,) uint8
    scalars: jnp.ndarray,       # (2,) int32: [length, prev_ov]
    weights: jnp.ndarray,       # (beta,) int32 block-hash weights
    group_operands,             # flat tuple, ops.GroupSpec order
    *,
    groups,
    p_total: int,
    tile: int,
    beta: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Raw pallas_call -> (ntiles, p_total) int32 per-tile counts.

    text_padded layout: [tile zeros | window padded to ntiles*tile | tile
    zeros] (the kernels/epsmc halo idiom).
    """
    ntiles = text_padded.shape[0] // tile - 2
    kernel = functools.partial(
        _mega_kernel, tile=tile, groups=groups, p_total=p_total, beta=beta
    )
    in_specs = [
        pl.BlockSpec((tile,), lambda i: (i,)),      # prev tile
        pl.BlockSpec((tile,), lambda i: (i + 1,)),  # current tile
        pl.BlockSpec((tile,), lambda i: (i + 2,)),  # next tile
        pl.BlockSpec((2,), lambda i: (0,)),         # [L, prev_ov]
        pl.BlockSpec((weights.shape[0],), lambda i: (0,)),
    ]
    for op in group_operands:
        # default-arg bind: a late-binding `op.ndim` would resolve to the
        # LAST operand's rank for every index map
        in_specs.append(
            pl.BlockSpec(op.shape, lambda i, nd=op.ndim: (0,) * nd)
        )
    return pl.pallas_call(
        kernel,
        grid=(ntiles,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, p_total), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ntiles, p_total), jnp.int32),
        interpret=interpret,
    )(text_padded, text_padded, text_padded, scalars, weights, *group_operands)
