"""repro.obs — the scan telemetry plane (DESIGN.md §13).

Zero-dependency tracing + metrics + flight recorder for the
streaming/sharded engine:

  * :mod:`repro.obs.trace`    — nestable spans, per-lane buffers,
    ``block_until_ready`` fencing, Chrome/Perfetto trace_event export;
  * :mod:`repro.obs.metrics`  — counters/gauges/histograms with a
    deterministic summary;
  * :mod:`repro.obs.recorder` — the :class:`Recorder` protocol threaded
    through ``StreamScanner`` / ``ShardedStreamScanner`` /
    ``RemoteRangeReader`` / ``run_with_retries``, plus the process-wide
    disabled :data:`NULL` recorder and :func:`logging_sink`.
"""

from repro.obs.metrics import Metrics
from repro.obs.recorder import NULL, Recorder, logging_sink
from repro.obs.trace import (
    NULL_SPAN,
    NullSpan,
    Span,
    TraceBuffer,
    to_chrome,
    write_chrome,
)

__all__ = [
    "Metrics",
    "NULL",
    "NULL_SPAN",
    "NullSpan",
    "Recorder",
    "Span",
    "TraceBuffer",
    "logging_sink",
    "to_chrome",
    "write_chrome",
]
