"""Counters, gauges, and histograms with a deterministic summary
(DESIGN.md §13).

The scan fabric's quantities of interest are small and enumerable —
retries, steals, seam corrections, faults injected, bytes scanned,
dispatches, GB/s, per-span latency distributions — so this is a
deliberately tiny registry, not a metrics framework:

  * ``count(name, n)``   — monotonic counters (retries, dispatches, bytes);
  * ``gauge(name, v)``   — last-write-wins values (chunk_bytes, GB/s);
  * ``observe(name, v)`` — histograms: running count/sum/min/max plus a
    bounded sample buffer (first ``MAX_SAMPLES`` observations) from which
    p50/p99 are computed, so summaries of million-event runs stay O(1)
    memory while short runs (every test, every bench) keep exact samples.

``summary()`` is sorted-key JSON-clean nested dicts and ``report()`` a
sorted fixed-format text block — deterministic given the same recorded
values, so tests and CI can assert on them and two renders of one run can
never disagree.  Thread-safe: every mutation takes the registry lock
(these are per-chunk/per-event rates, not per-byte — contention is noise).
"""

from __future__ import annotations

import threading
from typing import Dict, List


class Metrics:
    MAX_SAMPLES = 4096

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        # name -> [count, total, min, max, samples]
        self._hists: Dict[str, list] = {}

    def count(self, name: str, n=1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def gauge(self, name: str, value) -> None:
        with self._lock:
            self._gauges[name] = value

    def observe(self, name: str, value) -> None:
        value = float(value)
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = [0, 0.0, value, value, []]
            h[0] += 1
            h[1] += value
            h[2] = min(h[2], value)
            h[3] = max(h[3], value)
            if len(h[4]) < self.MAX_SAMPLES:
                h[4].append(value)

    # -- rendering ----------------------------------------------------------

    @staticmethod
    def _pct(samples: List[float], q: float) -> float:
        s = sorted(samples)
        return s[min(len(s) - 1, int(q * len(s)))]

    def summary(self) -> dict:
        """Nested dict, keys sorted, values plain Python numbers."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = {k: (h[0], h[1], h[2], h[3], list(h[4]))
                     for k, h in self._hists.items()}
        out = {
            "counters": {k: counters[k] for k in sorted(counters)},
            "gauges": {k: gauges[k] for k in sorted(gauges)},
            "histograms": {},
        }
        for name in sorted(hists):
            n, total, lo, hi, samples = hists[name]
            out["histograms"][name] = {
                "count": n,
                "sum": total,
                "min": lo,
                "max": hi,
                "mean": total / n if n else 0.0,
                "p50": self._pct(samples, 0.50) if samples else 0.0,
                "p99": self._pct(samples, 0.99) if samples else 0.0,
            }
        return out

    def report(self) -> str:
        """Fixed-format text block of the summary (one metric per line)."""
        s = self.summary()
        lines = []
        for k, v in s["counters"].items():
            lines.append(f"counter  {k} = {v}")
        for k, v in s["gauges"].items():
            lines.append(f"gauge    {k} = {v}")
        for k, h in s["histograms"].items():
            lines.append(
                f"hist     {k}: n={h['count']} sum={h['sum']:.6g} "
                f"p50={h['p50']:.6g} p99={h['p99']:.6g} max={h['max']:.6g}"
            )
        return "\n".join(lines)
