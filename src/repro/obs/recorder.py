"""The flight recorder: one handle for spans, instant events, and metrics,
threaded through the streaming/sharded engine (DESIGN.md §13).

Every layer that used to log or silently recover now reports to a
:class:`Recorder`: ``StreamScanner`` (per-chunk host_prep / device_put /
dispatch spans), ``ShardedStreamScanner`` (per-lane scan_range spans,
steal/shed/range_done events with exact byte ranges, straggler flags),
``RemoteRangeReader`` (per-part waits, timeouts, backoff retries),
``run_with_retries`` (retry/exhausted events), ``FaultPlan`` (injected
faults), and ``StopScanner`` (per-step stop-scan spans).  Tests and CI
assert on the structured events; humans open the Perfetto export.

The contract that keeps this affordable:

  * **The default is off and stays off the hot path.**  ``enabled=False``
    makes ``span()`` return the shared :data:`~repro.obs.trace.NULL_SPAN`
    and every metric call return immediately — no buffers written, no
    syncs, no fencing.  The engine calls the recorder unconditionally
    (no ``if tracing:`` forks in scan code); the budget for that is <2%
    throughput vs. no recorder at all, measured by
    ``benchmarks/run.py bench_obs`` (BENCH_obs.json).

  * **Instant events still reach the sinks when disabled.**  Sinks are
    ``fn(name, args)`` callables; :func:`logging_sink` formats one log
    line per event.  Modules hand their disabled default recorder a
    logging sink, so the pre-recorder log lines (auto-chunk probe,
    straggler flags, kernel fallback) keep appearing with no recorder
    attached — the log file is just another sink of the event stream.

  * **Enabled tracing fences.**  ``span.fence(value)`` blocks until
    ``value`` is device-ready inside the span (see ``trace.py``), so
    dispatch spans measure device time, not submission time.  Fencing
    serializes the double-buffered pipeline; that is the honest cost of
    attribution and exactly why it never happens when disabled.  Pass
    ``fence=False`` to trace submission-side timing with pipelining
    intact.
"""

from __future__ import annotations

import json
import logging
from pathlib import Path
from typing import Callable, Dict, List, Optional

from repro.obs.metrics import Metrics
from repro.obs.trace import (
    NULL_SPAN,
    NullSpan,
    Span,
    TraceBuffer,
    _PH_INSTANT,
    _PH_SPAN,
    _now,
    to_chrome,
    write_chrome,
)

Sink = Callable[[str, dict], None]


def logging_sink(logger: logging.Logger, level: int = logging.INFO) -> Sink:
    """A sink that renders each instant event as one log line:
    ``name k1=v1 k2=v2`` with keys sorted (deterministic)."""

    def sink(name: str, args: dict) -> None:
        if logger.isEnabledFor(level):
            kv = " ".join(f"{k}={args[k]}" for k in sorted(args))
            logger.log(level, "%s %s", name, kv)

    return sink


class Recorder:
    """Spans + events + metrics behind one handle (see module docstring;
    DESIGN.md §13 is the design, benchmarks/validate_trace.py the export
    contract the serving plane's dispatch lane also honors).

    ``span(name, lane=..., **args)`` returns a context manager timing a
    nested region on that lane; ``event(name, lane=..., **args)`` records
    an instant structured event (and feeds every sink, enabled or not);
    ``count``/``gauge``/``observe`` update the metrics registry.  The
    queries — ``events_named``, ``span_totals_ms``, ``summary``,
    ``report``, ``trace_json``, ``export_trace`` — serve tests, benches,
    and CI artifacts.
    """

    def __init__(
        self,
        *,
        enabled: bool = True,
        fence: bool = True,
        pid: int = 0,
        sinks: tuple = (),
    ):
        self.enabled = bool(enabled)
        self.fence_dispatches = bool(fence)
        self.pid = int(pid)
        self.sinks: List[Sink] = list(sinks)
        self.trace = TraceBuffer()
        self.metrics = Metrics()

    def add_sink(self, sink: Sink) -> "Recorder":
        self.sinks.append(sink)
        return self

    # -- recording ----------------------------------------------------------

    def span(self, name: str, *, lane: Optional[str] = None, **args):
        if not self.enabled:
            return NULL_SPAN
        return Span(
            name, args, self.trace.lane(lane), self.metrics,
            self.fence_dispatches,
        )

    def event(self, name: str, *, lane: Optional[str] = None, **args) -> None:
        for sink in self.sinks:
            sink(name, args)
        if not self.enabled:
            return
        buf = self.trace.lane(lane)
        buf.append((_PH_INSTANT, name, _now(), 0.0, args))
        self.metrics.count("event/" + name)

    def count(self, name: str, n=1) -> None:
        if self.enabled:
            self.metrics.count(name, n)

    def gauge(self, name: str, value) -> None:
        if self.enabled:
            self.metrics.gauge(name, value)

    def observe(self, name: str, value) -> None:
        if self.enabled:
            self.metrics.observe(name, value)

    # -- queries ------------------------------------------------------------

    def events_named(self, name: str) -> List[dict]:
        """Structured args of every instant event called ``name``, each
        augmented with its ``lane`` and ``ts``, in timestamp order."""
        out = []
        for lane, rows in self.trace.snapshot().items():
            for ph, ev_name, ts, _dur, args in rows:
                if ph == _PH_INSTANT and ev_name == name:
                    out.append({**args, "lane": lane, "ts": ts})
        out.sort(key=lambda e: e["ts"])
        return out

    def span_totals_ms(self) -> Dict[str, float]:
        """Total recorded duration per span name (ms), summed over lanes —
        the host-prep vs device_put vs dispatch breakdown benches emit."""
        totals: Dict[str, float] = {}
        for rows in self.trace.snapshot().values():
            for ph, name, _ts, dur, _args in rows:
                if ph == _PH_SPAN:
                    totals[name] = totals.get(name, 0.0) + dur * 1e3
        return {k: totals[k] for k in sorted(totals)}

    def trace_json(self) -> dict:
        return to_chrome(self.trace, pid=self.pid)

    def export_trace(self, path) -> Path:
        """Write the Chrome/Perfetto trace_event JSON artifact."""
        return write_chrome(self.trace, path, pid=self.pid)

    def summary(self) -> dict:
        """Deterministically ordered run summary: metrics + per-name event
        counts + per-name span totals."""
        event_counts: Dict[str, int] = {}
        span_counts: Dict[str, int] = {}
        for rows in self.trace.snapshot().values():
            for ph, name, _ts, _dur, _args in rows:
                if ph == _PH_INSTANT:
                    event_counts[name] = event_counts.get(name, 0) + 1
                elif ph == _PH_SPAN:
                    span_counts[name] = span_counts.get(name, 0) + 1
        totals = self.span_totals_ms()
        return {
            "metrics": self.metrics.summary(),
            "events": {k: event_counts[k] for k in sorted(event_counts)},
            "spans": {
                k: {"count": span_counts[k], "total_ms": totals.get(k, 0.0)}
                for k in sorted(span_counts)
            },
        }

    def report(self) -> str:
        """Human-readable summary block (stable ordering)."""
        s = self.summary()
        lines = ["== scan telemetry =="]
        for name, info in s["spans"].items():
            lines.append(
                f"span     {name}: n={info['count']} "
                f"total={info['total_ms']:.1f}ms"
            )
        for name, n in s["events"].items():
            lines.append(f"event    {name}: n={n}")
        body = self.metrics.report()
        if body:
            lines.append(body)
        return "\n".join(lines)

    def summary_json(self) -> str:
        return json.dumps(self.summary(), indent=1, sort_keys=True)


# The process-wide disabled recorder: what every instrumented layer falls
# back to when no recorder is passed.  No sinks, no buffers touched — the
# shape bench_obs's "none" column measures.
NULL = Recorder(enabled=False, fence=False)
