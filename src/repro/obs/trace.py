"""Trace spans, per-lane buffers, and Chrome/Perfetto export (DESIGN.md §13).

The streaming/sharded engine is a pipeline of host work (source reads,
decompression, window assembly), transfers (``device_put``), and
asynchronously dispatched device compute — wall-clock alone cannot say
where a flat scaling curve comes from.  This module is the timing
substrate the :class:`~repro.obs.recorder.Recorder` builds on:

  * :class:`Span` — a nestable timed region on one *lane*, recorded with
    the monotonic clock (``time.perf_counter``).  Spans are context
    managers; nesting needs no parent bookkeeping because the Chrome
    viewer nests complete events on one track by ``ts``/``dur``
    containment, which holds by construction (a child enters after and
    exits before its parent on the same lane).

  * **fencing** — JAX dispatch is asynchronous: a jitted call returns as
    soon as the work is enqueued, so a naive ``with span(): f(x)`` times
    the *submission*, hiding device time until some later sync.
    ``span.fence(value)`` calls ``jax.block_until_ready`` on ``value``
    INSIDE the span, so the recorded duration covers the device work.
    Fencing deliberately trades the engine's double-buffered pipelining
    for honest per-dispatch attribution — which is why it only happens
    under an *enabled* recorder (the no-op default never syncs, so the
    production pipeline shape is untouched).

  * :class:`TraceBuffer` — thread-safe per-lane event buffers.  A lane is
    one horizontal track in the trace (a Perfetto "thread"): explicit
    names for logical lanes (``shard3``, ``lane0``) so stolen ranges stay
    attributed to the lane that scanned them, the current thread's name
    otherwise.  Lane creation takes a lock once; appends are plain list
    appends.

  * :func:`to_chrome` — export as Chrome ``trace_event`` JSON
    (``{"traceEvents": [...]}``) loadable by ``chrome://tracing`` and
    https://ui.perfetto.dev.  Lanes become integer ``tid``s with
    ``thread_name`` metadata; timestamps are microseconds relative to the
    buffer's origin.  ``benchmarks/validate_trace.py`` is the stdlib-only
    schema gate CI runs over these exports.

Zero dependencies: ``jax`` is imported only inside ``fence`` on fenced
spans, so the module (and every no-op path) stays stdlib-only.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Dict, List, Optional

_now = time.perf_counter

# buffer rows: (ph, name, t_start, duration_s, args)
# ph is the Chrome phase: "X" complete span, "i" instant event
_PH_SPAN = "X"
_PH_INSTANT = "i"


class NullSpan:
    """The reusable do-nothing span a disabled recorder hands out: enter,
    exit, ``set``, and ``fence`` all no-op (``fence`` returns its argument
    WITHOUT syncing — the disabled path must never change the engine's
    async dispatch shape)."""

    __slots__ = ()

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "NullSpan":
        return self

    def fence(self, value):
        return value


NULL_SPAN = NullSpan()


class Span:
    """One timed region on one lane; appended to its buffer at exit.

    ``set(**attrs)`` attaches/updates args mid-span (e.g. the byte count
    known only after the window is assembled).  ``fence(value)`` blocks
    until ``value``'s device work is done — still inside the span — when
    the owning recorder fences, and is a pass-through otherwise.
    """

    __slots__ = ("name", "args", "t0", "_buf", "_metrics", "_fenced")

    def __init__(self, name: str, args: dict, buf: list, metrics, fenced: bool):
        self.name = name
        self.args = args
        self._buf = buf
        self._metrics = metrics
        self._fenced = fenced
        self.t0 = 0.0

    def __enter__(self) -> "Span":
        self.t0 = _now()
        return self

    def set(self, **attrs) -> "Span":
        self.args.update(attrs)
        return self

    def fence(self, value):
        if self._fenced and value is not None:
            import jax  # lazy: the no-op paths never touch jax

            jax.block_until_ready(value)
        return value

    def __exit__(self, *exc) -> bool:
        dur = _now() - self.t0
        self._buf.append((_PH_SPAN, self.name, self.t0, dur, self.args))
        if self._metrics is not None:
            self._metrics.observe("span/" + self.name, dur)
        return False


class TraceBuffer:
    """Thread-safe per-lane event buffers with one shared time origin.

    ``lane(name)`` returns the append target for that lane, creating it
    under the lock on first use; lookups after that are lock-free dict
    reads and appends are GIL-atomic list appends, so concurrent scan
    lanes never contend on a global buffer lock.
    """

    def __init__(self):
        self.t_origin = _now()
        self._lanes: Dict[str, List] = {}
        self._lock = threading.Lock()

    def lane(self, name: Optional[str] = None) -> list:
        if name is None:
            name = threading.current_thread().name
        buf = self._lanes.get(name)
        if buf is None:
            with self._lock:
                buf = self._lanes.setdefault(name, [])
        return buf

    def snapshot(self) -> Dict[str, list]:
        """Point-in-time copy of every lane's rows (safe to iterate while
        scans keep appending)."""
        with self._lock:
            lanes = list(self._lanes.items())
        return {name: list(buf) for name, buf in lanes}


def _jsonable(v):
    """Coerce span/event args to JSON-clean values: numpy scalars to
    Python numbers, bytes to their repr, everything unknown to str."""
    if isinstance(v, bool) or v is None or isinstance(v, (str, int, float)):
        return v
    if hasattr(v, "item"):  # numpy scalar
        try:
            return v.item()
        except Exception:
            return str(v)
    if isinstance(v, (bytes, bytearray)):
        return repr(bytes(v))
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    return str(v)


def to_chrome(buffers: TraceBuffer, *, pid: int = 0) -> dict:
    """Chrome ``trace_event`` JSON for the buffers' current contents.

    Lanes map to integer ``tid``s (named via ``thread_name`` metadata
    events) in sorted-lane order, so the export is deterministic for a
    given set of recorded rows.  Timestamps are µs since the buffer's
    origin; complete events carry ``dur``; instant events are
    thread-scoped (``"s": "t"``).
    """
    events: List[dict] = []
    lanes = buffers.snapshot()
    t0 = buffers.t_origin
    for tid, lane_name in enumerate(sorted(lanes)):
        events.append({
            "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
            "args": {"name": lane_name},
        })
        for ph, name, ts, dur, args in lanes[lane_name]:
            e = {
                "name": name,
                "cat": "scan",
                "ph": ph,
                "pid": pid,
                "tid": tid,
                "ts": round((ts - t0) * 1e6, 3),
                "args": {k: _jsonable(v) for k, v in args.items()},
            }
            if ph == _PH_SPAN:
                e["dur"] = round(dur * 1e6, 3)
            elif ph == _PH_INSTANT:
                e["s"] = "t"
            events.append(e)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome(buffers: TraceBuffer, path, *, pid: int = 0) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(to_chrome(buffers, pid=pid), indent=1))
    return path
