"""Optimizers (pure-pytree, optax-style triple: init / update).

AdamW with decoupled weight decay, global-norm gradient clipping, warmup +
cosine schedule.  Moments are always fp32 regardless of param dtype (bf16
params + fp32 Adam state is the large-scale default; states shard exactly
like their params — ZeRO-1 via GSPMD out_shardings).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    m: dict  # first moments (fp32, param-tree)
    v: dict  # second moments (fp32, param-tree)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _decay_mask(params):
    """Decay matrices; skip vectors (norm scales, biases, 1-D tables)."""
    return jax.tree_util.tree_map(lambda p: p.ndim >= 2, params)


def adamw_init(params, cfg: Optional[AdamWConfig] = None) -> AdamWState:
    del cfg
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros32, params),
        v=jax.tree_util.tree_map(zeros32, params),
    )


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mask = _decay_mask(params)

    def upd(p, g, m, v, decay):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + jnp.where(decay, cfg.weight_decay, 0.0) * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_mask = treedef.flatten_up_to(mask)
    out = [upd(p, g, m, v, d) for p, g, m, v, d in zip(flat_p, flat_g, flat_m, flat_v, flat_mask)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics


def sgd_update(grads, params, lr: float):
    """Plain SGD (used by tiny smoke tests)."""
    return jax.tree_util.tree_map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params,
        grads,
    )
