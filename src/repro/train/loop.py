"""Training loop: jit'd step with sharding, gradient accumulation,
checkpoint/restart, straggler watchdog, metrics logging.

Family-agnostic: pass any (loss_fn, params) pair; the LM example drivers in
examples/ use it with the byte-level pipeline.
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Callable, Iterable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.dist.fault_tolerance import InjectedFault, StepWatchdog
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    grad_accum: int = 1
    async_checkpoint: bool = True
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig, grad_accum: int = 1):
    """loss_fn(params, batch) -> scalar.  grad_accum > 1 scans microbatches
    (batch's leading dim must be divisible; accumulates in fp32)."""

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(i, b):
                return jax.tree_util.tree_map(
                    lambda x: x.reshape(grad_accum, -1, *x.shape[1:])[i], b
                )

            def body(carry, i):
                acc, ls = carry
                l, g = jax.value_and_grad(loss_fn)(params, micro(i, batch))
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g
                )
                return (acc, ls + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), jnp.arange(grad_accum)
            )
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
        new_p, new_s, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return new_p, new_s, metrics

    return step


def train(
    loss_fn: Callable,
    init_params,
    data: Iterable,
    cfg: TrainConfig,
    *,
    watchdog: Optional[StepWatchdog] = None,
    fault_at_step: Optional[int] = None,
    log: Callable[[str], None] = print,
):
    """Returns (params, opt_state, history).  Resumes from cfg.ckpt_dir if a
    checkpoint exists; `fault_at_step` injects a crash (restart tests)."""
    params = init_params
    opt_state = adamw_init(params)
    start = 0
    if cfg.ckpt_dir and ckpt.latest_step(cfg.ckpt_dir) is not None:
        (params, opt_state), start = ckpt.restore((params, opt_state), cfg.ckpt_dir)
        log(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(loss_fn, cfg.opt, cfg.grad_accum))
    history = []
    data_it = iter(data)
    for step in range(start, cfg.steps):
        if watchdog:
            watchdog.start_step(step)
        batch = next(data_it)
        batch = jax.tree_util.tree_map(jnp.asarray, batch)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if fault_at_step is not None and step == fault_at_step:
            raise InjectedFault(f"injected node failure at step {step}")
        if watchdog:
            action = watchdog.end_step()
            if action == "checkpoint" and cfg.ckpt_dir:
                ckpt.save((params, opt_state), cfg.ckpt_dir, step + 1,
                          keep=cfg.keep_ckpts)
        loss = float(metrics["loss"])
        history.append(loss)
        if step % cfg.log_every == 0:
            log(f"step {step}: loss={loss:.4f} gnorm={float(metrics['grad_norm']):.3f}")
        if cfg.ckpt_dir and (step + 1) % cfg.ckpt_every == 0:
            ckpt.save((params, opt_state), cfg.ckpt_dir, step + 1,
                      keep=cfg.keep_ckpts, async_=cfg.async_checkpoint)
    if cfg.ckpt_dir:
        t = ckpt.save((params, opt_state), cfg.ckpt_dir, cfg.steps,
                      keep=cfg.keep_ckpts)
    return params, opt_state, history
