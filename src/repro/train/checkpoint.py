"""Checkpointing: atomic, async, keep-K, restore-with-resharding.

Layout:  <dir>/step_<N>/  with one .npy per pytree leaf (path-encoded
filename) + manifest.json (tree structure, shapes, dtypes, step).
Writes go to a tmp dir first and are os.rename'd into place — a crash
mid-save never corrupts the latest checkpoint (fault-tolerance contract).

Restore takes an optional shardings pytree: arrays are jax.device_put with
the TARGET sharding, so a checkpoint written on one mesh restores onto any
other mesh/device-count (elastic up/down-scaling path — see
dist/fault_tolerance.py and tests/test_checkpoint.py).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import ml_dtypes  # registers bfloat16/float8 dtypes with numpy
import numpy as np

import jax


_SEP = "__"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        name = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        items.append((name or "leaf", leaf))
    return items, jax.tree_util.tree_structure(tree)


def save(tree, directory, step: int, *, keep: int = 3, async_: bool = False):
    """Save a pytree of (possibly sharded) jax arrays / numpy arrays."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    items, _ = _flatten(tree)
    # device_get BEFORE handing to the writer thread (ordering w.r.t. donation)
    host_items = [(n, np.asarray(jax.device_get(x))) for n, x in items]

    def _write():
        tmp = directory / f".tmp_step_{step}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": []}
        for name, arr in host_items:
            fname = f"{name}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {"name": name, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = directory / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        _gc(directory, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(directory: Path, keep: int):
    steps = sorted(directory.glob("step_*"))
    for old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(directory.glob("step_*"))
    if not steps:
        return None
    return int(re.search(r"step_(\d+)", steps[-1].name).group(1))


def restore(tree_like, directory, step: Optional[int] = None, *, shardings=None):
    """Restore into the structure of `tree_like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of Sharding —
    leaves are device_put with the TARGET sharding (elastic restore)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    by_name = {m["name"]: m for m in manifest["leaves"]}

    items, treedef = _flatten(tree_like)
    leaves = []
    sh_items = None
    if shardings is not None:
        sh_items, _ = _flatten(shardings)
    for i, (name, like) in enumerate(items):
        meta = by_name[name]
        arr = np.load(d / meta["file"])
        if str(arr.dtype) != meta["dtype"]:
            # exotic dtypes (bfloat16, float8) round-trip through numpy as
            # void; view them back via the ml_dtypes registry
            arr = arr.view(np.dtype(meta["dtype"]))
        expect = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch for {name}: {arr.shape} vs {expect}")
        if sh_items is not None:
            arr = jax.device_put(arr, sh_items[i][1])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]
