"""JSON-lines TCP front end for the query plane (docs/serving.md).

One newline-delimited JSON object per request/response; the server answers a
connection's requests in order but serves every connection concurrently on
the asyncio loop, so cross-connection queries coalesce in the underlying
:class:`~repro.serve.query_plane.QueryPlane` (DESIGN.md §15).  The protocol
is deliberately minimal — a demo front door for the plane, not a product
server; examples/serve_grep.py drives it end to end.

Requests (``id`` is echoed back; binary payloads ride base64 fields):

  {"op": "ping", "id": 1}
  {"op": "add_corpus", "id": 2, "corpus": "logs", "text": "..."}      # or text_b64
  {"op": "query", "id": 3, "corpus": "logs", "patterns": ["err"],    # or patterns_b64
   "mode": "count" | "any" | "match", "k": 0}
  {"op": "stats", "id": 4}

Responses carry ``{"id", "ok"}`` plus op-specific fields; failures map the
plane's exceptions onto HTTP-style statuses: admission rejection -> 429,
unknown corpus -> 404, malformed request -> 400, unexpected dispatch
failure -> 500 (the connection stays open).
"""

from __future__ import annotations

import asyncio
import base64
import itertools
import json
from typing import Optional, Tuple

from repro.serve.query_plane import (
    QueryPlane,
    QueryRejected,
    UnknownCorpus,
)

# asyncio stream buffer limit: a single add_corpus line carries the whole
# base64 payload, so the 64 KiB default would reset large uploads
STREAM_LIMIT = 1 << 27


def _decode_text(req: dict) -> bytes:
    if "text_b64" in req:
        return base64.b64decode(req["text_b64"])
    return str(req["text"]).encode("utf-8", errors="surrogateescape")


def _decode_patterns(req: dict) -> list:
    if "patterns_b64" in req:
        return [base64.b64decode(p) for p in req["patterns_b64"]]
    return [
        str(p).encode("utf-8", errors="surrogateescape")
        for p in req["patterns"]
    ]


class GrepServer:
    """asyncio TCP server wrapping a :class:`QueryPlane`.

    ``await start()`` binds (ephemeral port by default) and returns the
    (host, port) address; ``await stop()`` drains the plane and closes.
    Also an async context manager: ``async with GrepServer(plane) as addr:``.
    """

    def __init__(self, plane: QueryPlane):
        self.plane = plane
        self._server: Optional[asyncio.AbstractServer] = None
        self.address: Optional[Tuple[str, int]] = None

    async def start(
        self, host: str = "127.0.0.1", port: int = 0
    ) -> Tuple[str, int]:
        self._server = await asyncio.start_server(
            self._handle, host, port, limit=STREAM_LIMIT
        )
        sock = self._server.sockets[0].getsockname()
        self.address = (sock[0], sock[1])
        return self.address

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        await self.plane.close()

    async def __aenter__(self) -> Tuple[str, int]:
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- connection handling ------------------------------------------------

    async def _handle(self, reader, writer) -> None:
        try:
            while True:
                line = await reader.readline()
                if not line:
                    break
                try:
                    req = json.loads(line)
                except json.JSONDecodeError as exc:
                    resp = {"id": None, "ok": False, "status": 400,
                            "error": f"bad json: {exc.msg}"}
                else:
                    resp = await self._serve_one(req)
                writer.write(json.dumps(resp).encode() + b"\n")
                await writer.drain()
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _serve_one(self, req: dict) -> dict:
        rid = req.get("id")
        op = req.get("op")
        try:
            if op == "ping":
                return {"id": rid, "ok": True, "pong": True}
            if op == "add_corpus":
                digest = self.plane.add_corpus(
                    str(req["corpus"]), _decode_text(req)
                )
                return {"id": rid, "ok": True, "digest": digest}
            if op == "query":
                result = await self.plane.query(
                    str(req["corpus"]),
                    _decode_patterns(req),
                    mode=req.get("mode", "count"),
                    k=int(req.get("k", 0)),
                )
                resp = {
                    "id": rid, "ok": True,
                    "counts": [int(c) for c in result.counts],
                    "cached": bool(result.cached),
                    "batched": int(result.batched),
                }
                if result.positions is not None:
                    resp["positions"] = [
                        [int(i) for i in p] for p in result.positions
                    ]
                return resp
            if op == "stats":
                return {"id": rid, "ok": True, "stats": self.plane.stats(),
                        "slo": self.plane.slo_report()}
            return {"id": rid, "ok": False, "status": 400,
                    "error": f"unknown op: {op!r}"}
        except QueryRejected as exc:
            return {"id": rid, "ok": False, "status": 429,
                    "error": "rejected", "detail": str(exc)}
        except UnknownCorpus as exc:
            return {"id": rid, "ok": False, "status": 404,
                    "error": "unknown_corpus", "detail": str(exc)}
        except (KeyError, ValueError, TypeError) as exc:
            return {"id": rid, "ok": False, "status": 400,
                    "error": f"{type(exc).__name__}: {exc}"}
        except Exception as exc:  # noqa: BLE001 — e.g. a failed dispatch
            # fanned out of _run_batch; answer 500 and keep the connection
            # alive instead of tearing it (and its queued requests) down
            return {"id": rid, "ok": False, "status": 500,
                    "error": "internal", "detail": f"{exc}"}


class GrepClient:
    """Minimal JSON-lines client: one in-flight request per connection
    (open several clients for concurrency — that is exactly what makes the
    server side coalesce)."""

    _ids = itertools.count(1)

    def __init__(self, reader, writer):
        self._reader = reader
        self._writer = writer

    @classmethod
    async def connect(cls, host: str, port: int) -> "GrepClient":
        reader, writer = await asyncio.open_connection(
            host, port, limit=STREAM_LIMIT
        )
        return cls(reader, writer)

    async def request(self, **fields) -> dict:
        fields.setdefault("id", next(self._ids))
        self._writer.write(json.dumps(fields).encode() + b"\n")
        await self._writer.drain()
        line = await self._reader.readline()
        if not line:
            raise ConnectionError("server closed the connection")
        return json.loads(line)

    async def ping(self) -> dict:
        return await self.request(op="ping")

    async def add_corpus(self, corpus: str, data: bytes) -> dict:
        return await self.request(
            op="add_corpus", corpus=corpus,
            text_b64=base64.b64encode(bytes(data)).decode("ascii"),
        )

    async def query(
        self, corpus: str, patterns, *, mode: str = "count", k: int = 0
    ) -> dict:
        return await self.request(
            op="query", corpus=corpus, mode=mode, k=k,
            patterns_b64=[
                base64.b64encode(bytes(p)).decode("ascii") for p in patterns
            ],
        )

    async def stats(self) -> dict:
        return await self.request(op="stats")

    async def close(self) -> None:
        self._writer.close()
        try:
            await self._writer.wait_closed()
        except (ConnectionError, OSError):
            pass
