"""Grep-as-a-service: the async query plane over the jit'd engine.

The paper's thesis — packed word-RAM instructions make short-pattern search
dispatch-bound, not compute-bound — inverts at service scale: when thousands
of users grep the same hot corpora, the scarce resource is DISPATCHES, not
byte-compares.  The engine already answers P patterns x B texts in one
``count_many``/``match_many`` call (DESIGN.md §7, §14), so the serving move
is to make concurrent requests SHARE dispatches: coalesce every query that
arrives within a micro-batching window against the same corpus into one
union pattern set, run one jitted call, and scatter the per-pattern results
back to their futures.  DESIGN.md §15 states the correctness argument (every
engine route is exact, so a coalesced batch is bit-identical to per-query
dispatches) and the cache/backpressure model; docs/serving.md is the
operator guide.

Layering (this module is pure asyncio + engine calls; the wire protocol
lives in serve/server.py):

  * :class:`ServiceConfig` — the operator knobs: coalescing window and batch
    cap, admission depth, corpus-cache byte budget, result-cache entries.
  * :class:`CorpusCache` — device-resident :class:`~repro.core.engine.
    TextIndex` LRU keyed by corpus id, evicting by measured device bytes.
  * :class:`QueryPlane` — ``await plane.query(corpus_id, patterns, ...)``:
    admission control (bounded pending depth, :class:`QueryRejected` 429s),
    per-(corpus, mode, k) coalescing buckets, canonical pow2-padded union
    compilation through ``compile_patterns_cached(..., canonical=True)`` so
    every batch shape-signature hits one jitted executable (no per-union
    XLA retrace), a keyed recent-result cache, and per-request latency
    histograms / spans through the PR-8 :class:`~repro.obs.recorder.
    Recorder`.

Trace discipline: request lifecycle telemetry is instant events + metric
observations (requests from concurrent asyncio tasks interleave, so spans
would violate the per-lane nesting contract benchmarks/validate_trace.py
enforces); proper X-spans are emitted only from the single-threaded dispatch
executor on the dedicated "dispatch" lane, where nesting is real.
"""

from __future__ import annotations

import asyncio
import dataclasses
import hashlib
import time
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.obs.recorder import NULL, Recorder


class QueryRejected(RuntimeError):
    """Admission control turned the query away (HTTP-429 analogue): the
    plane already holds ``max_pending`` queries that have been admitted but
    not yet answered.  Clients should back off and retry; the server maps
    this to ``{"error": "rejected", "status": 429}``."""


class UnknownCorpus(KeyError):
    """The corpus id is not resident and the plane has no ``loader`` to
    bring it back (HTTP-404 analogue).  Seen after LRU eviction when the
    operator runs without a reload hook — see docs/serving.md."""


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Operator knobs for the query plane (docs/serving.md has the tuning
    guide; DESIGN.md §15 the model behind each).

    coalesce_ms     micro-batching window: the first query to a
                    (corpus, mode, k) bucket opens it, everything arriving
                    within it joins the same dispatch.  Under flush_on_idle
                    (the default) the timer is only a liveness backstop —
                    it re-arms while the dispatcher is busy rather than cut
                    a growing batch into fragments — so its exact value
                    barely matters; under flush_on_idle=False it is the
                    fixed batching window.  0 disables time-based
                    coalescing.
    flush_on_idle   dispatch-clocked batching (default True): a bucket
                    flushes IMMEDIATELY when no dispatch is in flight, and
                    otherwise accumulates until a running dispatch
                    completes (or max_batch fires).  An idle service adds
                    zero batching latency; a busy one batches exactly as
                    hard as the dispatcher's backlog — False reverts to a
                    fixed-window batcher (tests use this for deterministic
                    parking).
    max_batch       flush the bucket early once it holds this many queries.
    max_pending     admission depth: queries admitted but unanswered; above
                    this, ``query()`` raises :class:`QueryRejected`.
    corpus_budget_bytes   device-byte budget for resident TextIndexes; LRU
                    eviction keeps the measured total under this.
    result_cache_entries  recent-result cache capacity (0 disables).
    """

    coalesce_ms: float = 2.0
    max_batch: int = 64
    max_pending: int = 256
    corpus_budget_bytes: int = 1 << 30
    result_cache_entries: int = 4096
    flush_on_idle: bool = True


@dataclasses.dataclass
class CorpusEntry:
    """One resident corpus: its device index plus identity/size metadata."""

    corpus_id: str
    index: engine.TextIndex
    digest: str          # sha1 of the raw bytes — result-cache identity
    nbytes: int          # measured device bytes (text+packed+block_fp+lengths)
    raw_len: int         # true byte length (before pow2 padding)


def _index_nbytes(index: engine.TextIndex) -> int:
    return int(
        index.text.nbytes + index.packed.nbytes
        + index.block_fp.nbytes + index.lengths.nbytes
    )


def _pow2_ceil(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


class CorpusCache:
    """Device-resident TextIndex LRU keyed by corpus id (DESIGN.md §15).

    ``put`` builds the index with the corpus padded to a power-of-two length
    (true length carried in ``TextIndex.lengths``, so padding can never
    match) — together with canonical plans this pins the jit cache key to
    (pow2 n, pow2 P) shape signatures.  Eviction is least-recently-queried
    by measured device bytes against the configured budget; every eviction
    emits a ``corpus_evict`` event so the flight recorder shows WHY a later
    query missed."""

    def __init__(self, budget_bytes: int, recorder: Recorder = NULL):
        self.budget = int(budget_bytes)
        self.rec = recorder
        self._entries: "OrderedDict[str, CorpusEntry]" = OrderedDict()

    def put(self, corpus_id: str, data) -> CorpusEntry:
        return self.install(self.build(corpus_id, data))

    def build(self, corpus_id: str, data) -> CorpusEntry:
        """Build the device index for ``data`` WITHOUT touching the LRU —
        pure and thread-safe, so the plane's loader path can run it on an
        executor and keep the event loop responsive during the build."""
        raw = bytes(data.tobytes() if isinstance(data, np.ndarray) else data)
        if not raw:
            raise ValueError("corpus must be non-empty")
        arr = np.frombuffer(raw, np.uint8)
        n = _pow2_ceil(arr.size)
        padded = np.zeros((1, n), np.uint8)
        padded[0, : arr.size] = arr
        index = engine.build_index(padded, np.array([arr.size], np.int32))
        jax.block_until_ready(index.packed)
        return CorpusEntry(
            corpus_id=str(corpus_id),
            index=index,
            digest=hashlib.sha1(raw).hexdigest(),
            nbytes=_index_nbytes(index),
            raw_len=arr.size,
        )

    def install(self, entry: CorpusEntry) -> CorpusEntry:
        """Insert a built entry into the LRU and evict over budget (event-
        loop side of ``put``; single-threaded with ``get``)."""
        self._entries.pop(entry.corpus_id, None)
        self._entries[entry.corpus_id] = entry
        self.rec.event(
            "corpus_load", corpus=entry.corpus_id, nbytes=entry.nbytes,
            raw_len=entry.raw_len, n=entry.index.text.shape[1],
        )
        self._evict_over_budget(keep=entry.corpus_id)
        return entry

    def get(self, corpus_id: str) -> Optional[CorpusEntry]:
        entry = self._entries.get(str(corpus_id))
        if entry is not None:
            self._entries.move_to_end(str(corpus_id))
        return entry

    def _evict_over_budget(self, keep: str) -> None:
        while self.total_bytes > self.budget and len(self._entries) > 1:
            victim_id = next(
                cid for cid in self._entries if cid != keep
            )
            victim = self._entries.pop(victim_id)
            self.rec.event(
                "corpus_evict", corpus=victim_id, nbytes=victim.nbytes,
                resident=len(self._entries),
            )
            self.rec.count("service.corpus_evictions")

    @property
    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    def ids(self) -> Tuple[str, ...]:
        """Resident corpus ids, least- to most-recently used."""
        return tuple(self._entries)


def _as_pattern_bytes(p) -> bytes:
    if isinstance(p, (bytes, bytearray, memoryview)):
        b = bytes(p)
    elif isinstance(p, str):
        b = p.encode("utf-8", errors="surrogateescape")
    elif isinstance(p, np.ndarray):
        b = (p if p.dtype == np.uint8 else p.astype(np.uint8)).tobytes()
    else:
        b = np.asarray(p).astype(np.uint8).tobytes()
    if not b:
        raise ValueError("patterns must be non-empty byte strings")
    return b


def _filler_pattern(m: int, i: int) -> bytes:
    """Deterministic padding pattern #i of length m.  Content is irrelevant
    to correctness (filler rows are simply never read back; a collision
    with a real pattern just duplicates a row) — it only needs to be a
    fixed function of (m, i) so padded unions are reproducible."""
    return bytes((157 * i + 89 * j + 13) % 256 for j in range(m))


def canonical_union(
    patterns: Sequence[bytes],
) -> Tuple[Tuple[bytes, ...], Dict[bytes, int]]:
    """Dedup + order + pad a batch's pattern multiset into the canonical
    union the coalesced dispatch compiles (DESIGN.md §15).

    Unique patterns are grouped by length (lengths ascending, first-seen
    order within a group — matching compile_patterns' grouping) and each
    length group is padded to the next power of two with deterministic
    filler patterns, so the compiled plans' shape signature depends only on
    the multiset of (length, pow2 group size) — the canonical-plan jit
    cache key.  Returns the padded union plus the pattern -> union-position
    map used to scatter engine output rows back to individual queries."""
    seen: "OrderedDict[bytes, None]" = OrderedDict()
    for p in patterns:
        seen.setdefault(p, None)
    by_len: Dict[int, List[bytes]] = {}
    for p in seen:
        by_len.setdefault(len(p), []).append(p)
    union: List[bytes] = []
    position: Dict[bytes, int] = {}
    for m in sorted(by_len):
        group = by_len[m]
        for p in group:
            position[p] = len(union)
            union.append(p)
        pad = _pow2_ceil(len(group)) - len(group)
        for i in range(pad):
            union.append(_filler_pattern(m, i))
    return tuple(union), position


@dataclasses.dataclass
class QueryResult:
    """Answer to one ``QueryPlane.query`` call.

    ``counts`` is int32[len(patterns)] in the REQUEST's pattern order
    (modes "count" and "any"; for "any" it still carries the exact counts —
    ``hits`` derives from them).  ``positions`` (mode "match" only) is one
    int64 array of match-start offsets per requested pattern.  ``cached``
    marks a result-cache hit; ``batched`` is how many queries shared the
    dispatch that produced this answer (1 = it ran alone)."""

    corpus_id: str
    mode: str
    k: int
    patterns: Tuple[bytes, ...]
    counts: Optional[np.ndarray] = None
    positions: Optional[Tuple[np.ndarray, ...]] = None
    cached: bool = False
    batched: int = 1

    @property
    def hits(self) -> Optional[np.ndarray]:
        return None if self.counts is None else self.counts > 0


class _Request:
    __slots__ = ("patterns", "future", "t0")

    def __init__(self, patterns: Tuple[bytes, ...], future, t0: float):
        self.patterns = patterns
        self.future = future
        self.t0 = t0


class _Batch:
    __slots__ = ("key", "entry", "mode", "k", "requests", "timer")

    def __init__(self, key, entry: CorpusEntry, mode: str, k: int):
        self.key = key
        self.entry = entry
        self.mode = mode
        self.k = k
        self.requests: List[_Request] = []
        self.timer: Optional[asyncio.TimerHandle] = None


_MODES = ("count", "any", "match")


class QueryPlane:
    """Coalescing asyncio front end over the batched engine (DESIGN.md §15).

    ``await query(corpus_id, patterns, mode=..., k=...)`` resolves to a
    :class:`QueryResult` whose values are bit-identical to a standalone
    ``count_many``/``match_many`` over the same corpus — coalescing changes
    WHEN and WITH WHOM a query is answered, never WHAT the answer is.

    ``loader`` (optional) maps a corpus id to its raw bytes; with it, a
    query against an evicted corpus transparently reloads instead of
    raising :class:`UnknownCorpus`.  ``recorder`` threads the PR-8 flight
    recorder through: request/batch events, p50/p99 latency histograms
    (``slo_report()``), and dispatch-lane spans in the exported trace.

    Dispatches run on a single worker thread (``run_in_executor``) so the
    event loop keeps admitting and coalescing while the device is busy —
    arrivals during a dispatch accumulate into the NEXT batch, which is
    what makes coalescing win under load even with coalesce_ms=0."""

    def __init__(
        self,
        config: Optional[ServiceConfig] = None,
        *,
        loader: Optional[Callable[[str], bytes]] = None,
        recorder: Recorder = NULL,
    ):
        self.cfg = config or ServiceConfig()
        self.rec = recorder
        self.loader = loader
        self.corpora = CorpusCache(self.cfg.corpus_budget_bytes, recorder)
        self._batches: Dict[tuple, _Batch] = {}
        self._inflight = 0  # batches flushed but not yet answered
        self._tasks: set = set()
        self._results: "OrderedDict[tuple, QueryResult]" = OrderedDict()
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="svc-dispatch"
        )
        self._pending = 0
        self._reloads: Dict[str, asyncio.Task] = {}
        self.counters = {
            "requests": 0, "rejected": 0, "result_cache_hits": 0,
            "dispatches": 0, "dispatched_queries": 0, "corpus_reloads": 0,
        }

    # -- corpus management --------------------------------------------------

    def add_corpus(self, corpus_id: str, data) -> str:
        """Make ``data`` resident (device-side index built now, LRU-tracked);
        returns the content digest used in result-cache keys."""
        return self.corpora.put(corpus_id, data).digest

    async def _resident(self, corpus_id: str) -> CorpusEntry:
        cid = str(corpus_id)
        entry = self.corpora.get(cid)
        if entry is not None:
            return entry
        if self.loader is None:
            raise UnknownCorpus(cid)
        # loader + index build run on the executor so a reload never stalls
        # the event loop (admission, coalescing, other connections); one
        # in-flight reload per corpus id — concurrent misses share it
        task = self._reloads.get(cid)
        if task is None:
            task = asyncio.get_running_loop().create_task(self._reload(cid))
            self._reloads[cid] = task
            task.add_done_callback(lambda _t: self._reloads.pop(cid, None))
        return await task

    async def _reload(self, cid: str) -> CorpusEntry:
        loop = asyncio.get_running_loop()
        entry = await loop.run_in_executor(
            self._pool, lambda: self.corpora.build(cid, self.loader(cid))
        )
        self.corpora.install(entry)
        self.counters["corpus_reloads"] += 1
        self.rec.count("service.corpus_reloads")
        return entry

    # -- the query path -----------------------------------------------------

    async def query(
        self,
        corpus_id: str,
        patterns: Sequence,
        *,
        mode: str = "count",
        k: int = 0,
    ) -> QueryResult:
        """Answer one grep query; may share its engine dispatch with every
        other in-window query against the same (corpus, mode, k).

        Raises :class:`QueryRejected` when admission depth is exhausted and
        :class:`UnknownCorpus` for a non-resident corpus without a loader.
        Exact occurrence semantics are the engine's (DESIGN.md §7): ``k``
        is the per-byte mismatch budget (§8)."""
        if mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}")
        t0 = time.perf_counter()
        pats = tuple(_as_pattern_bytes(p) for p in patterns)
        if not pats:
            raise ValueError("at least one pattern required")
        self.counters["requests"] += 1
        self.rec.count("service.requests")
        entry = await self._resident(corpus_id)

        ckey = (entry.digest, mode, int(k), pats)
        hit = self._cache_get(ckey)
        if hit is not None:
            self.counters["result_cache_hits"] += 1
            self.rec.count("service.result_cache_hits")
            self._observe_latency(t0, cached=True)
            return dataclasses.replace(hit, cached=True)

        if self._pending >= self.cfg.max_pending:
            self.counters["rejected"] += 1
            self.rec.count("service.rejected")
            self.rec.event(
                "query_rejected", corpus=str(corpus_id),
                pending=self._pending, max_pending=self.cfg.max_pending,
            )
            raise QueryRejected(
                f"admission queue full ({self._pending} pending)"
            )
        self._pending += 1

        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()
        req = _Request(pats, fut, t0)
        # the digest keys the bucket: if add_corpus/reload replaces the
        # content while a bucket is open, later queries open a FRESH bucket
        # against the new index instead of joining one that would answer
        # them (and populate the result cache) from the old content
        bkey = (str(corpus_id), entry.digest, mode, int(k))
        batch = self._batches.get(bkey)
        if batch is None:
            batch = _Batch(bkey, entry, mode, int(k))
            self._batches[bkey] = batch
            if self.cfg.coalesce_ms > 0 or not self.cfg.flush_on_idle:
                # coalesce_ms <= 0 under flush_on_idle: no timer at all —
                # liveness comes from the immediate-idle flush below and
                # the dispatch-completion FIFO flush in _run_batch
                batch.timer = loop.call_later(
                    max(0.0, self.cfg.coalesce_ms) / 1e3,
                    self._timer_fire, bkey, batch,
                )
        batch.requests.append(req)
        if len(batch.requests) >= self.cfg.max_batch or (
            self.cfg.flush_on_idle and self._inflight == 0
        ):
            # dispatch-clocked batching: never hold a query while the
            # dispatcher idles — arrivals during the dispatch coalesce
            # into the NEXT batch (flushed from _run_batch's finally)
            self._flush_batch(bkey, batch)
        try:
            result = await fut
        finally:
            self._pending -= 1
        self._cache_put(ckey, result)
        self._observe_latency(t0, cached=False)
        return result

    async def flush(self) -> None:
        """Flush every open coalescing bucket now and wait for the resulting
        dispatches (tests and graceful shutdown; not needed in steady state
        — timers flush on their own)."""
        for bkey, batch in list(self._batches.items()):
            self._flush_batch(bkey, batch)
        while self._tasks:
            await asyncio.gather(*list(self._tasks), return_exceptions=True)

    async def close(self) -> None:
        """Drain in-flight work and release the dispatch thread."""
        await self.flush()
        self._pool.shutdown(wait=True)

    # -- coalescing internals ----------------------------------------------

    def _timer_fire(self, bkey: tuple, batch: _Batch) -> None:
        if self._batches.get(bkey) is not batch:
            return  # already flushed (max_batch vs timer race)
        if self.cfg.flush_on_idle and self._inflight > 0:
            # Dispatch-clocked mode: the window must not CUT a batch while
            # the dispatcher is busy — a flush now would only queue a
            # fragment behind the running dispatch (measured: 2 ms slices
            # of a 15 ms dispatch shrink batches ~7x).  Re-arm and let the
            # completion-time FIFO flush in _run_batch take the bucket; the
            # timer survives purely as a liveness backstop.
            loop = asyncio.get_running_loop()
            batch.timer = loop.call_later(
                max(0.0, self.cfg.coalesce_ms) / 1e3,
                self._timer_fire, bkey, batch,
            )
            return
        self._flush_batch(bkey, batch)

    def _flush_batch(self, bkey: tuple, batch: _Batch) -> None:
        if self._batches.get(bkey) is not batch:
            return  # already flushed (max_batch vs timer race)
        del self._batches[bkey]
        if batch.timer is not None:
            batch.timer.cancel()
        if not batch.requests:
            return
        self._inflight += 1
        loop = asyncio.get_running_loop()
        task = loop.create_task(self._run_batch(batch))
        self._tasks.add(task)
        task.add_done_callback(self._tasks.discard)

    async def _run_batch(self, batch: _Batch) -> None:
        loop = asyncio.get_running_loop()
        try:
            per_request = await loop.run_in_executor(
                self._pool, self._dispatch, batch
            )
            for req, result in zip(batch.requests, per_request):
                if not req.future.done():
                    req.future.set_result(result)
        except Exception as exc:  # noqa: BLE001 — fan the failure out
            for req in batch.requests:
                if not req.future.done():
                    req.future.set_exception(
                        RuntimeError(f"dispatch failed: {exc!r}")
                    )
        finally:
            self._inflight -= 1
            if (
                self.cfg.flush_on_idle
                and self._inflight == 0
                and self._batches
            ):
                # dispatcher went idle with queries parked: flush the
                # OLDEST bucket now (FIFO), the rest follow as dispatches
                # complete or their coalesce_ms caps fire
                bkey = next(iter(self._batches))
                self._flush_batch(bkey, self._batches[bkey])

    def _dispatch(self, batch: _Batch) -> List[QueryResult]:
        """Runs on the single dispatch thread: compile the canonical union,
        one jitted engine call, scatter rows back per request."""
        rec = self.rec
        entry, mode, k = batch.entry, batch.mode, batch.k
        requests = batch.requests
        flat = [p for r in requests for p in r.patterns]
        with rec.span(
            "service_batch", lane="dispatch", corpus=entry.corpus_id,
            queries=len(requests), mode=mode, k=k,
        ) as sp:
            union, position = canonical_union(flat)
            with rec.span("plan_union", lane="dispatch", p_union=len(union)):
                plans = engine.compile_patterns_cached(
                    union, k=k, canonical=True, recorder=rec,
                )
            order = engine.plan_order(plans)
            inv = np.empty(order.size, np.int64)
            inv[order] = np.arange(order.size)
            row_of = None
            with rec.span(
                "engine_dispatch", lane="dispatch",
                p_union=len(union), n=entry.index.n,
            ) as dsp:
                if mode == "match":
                    mask = engine.match_many_jit(entry.index, plans, k=k)
                    # transfer only the rows some query asked for, padded to
                    # a pow2 row count so the eager gather's executable is
                    # shared across batch compositions (filler rows and
                    # unrequested duplicates never cross the wire)
                    need = np.unique(np.asarray(
                        [inv[position[p]] for r in requests
                         for p in r.patterns], np.int64,
                    ))
                    pad = _pow2_ceil(need.size) - need.size
                    need_pad = np.concatenate(
                        [need, np.repeat(need[-1:], pad)]
                    )
                    sub = mask[0][jnp.asarray(need_pad)]
                    out = dsp.fence(jax.device_get(sub))
                    row_of = {int(r): i for i, r in enumerate(need)}
                else:
                    counts = engine.count_many_jit(entry.index, plans, k=k)
                    out = dsp.fence(jax.device_get(counts[0]))
            out = np.asarray(out)
            sp.set(p_union=len(union))
        self.counters["dispatches"] += 1
        self.counters["dispatched_queries"] += len(requests)
        rec.count("service.dispatches")
        rec.observe("service.batch_queries", len(requests))
        rec.observe("service.batch_patterns", len(union))

        results: List[QueryResult] = []
        for req in requests:
            rows = np.asarray(
                [inv[position[p]] for p in req.patterns], np.int64
            )
            if mode == "match":
                positions = tuple(
                    np.flatnonzero(out[row_of[int(r)]]).astype(np.int64)
                    for r in rows
                )
                counts = np.asarray(
                    [p.size for p in positions], np.int32
                )
                results.append(QueryResult(
                    corpus_id=entry.corpus_id, mode=mode, k=k,
                    patterns=req.patterns, counts=counts,
                    positions=positions, batched=len(requests),
                ))
            else:
                results.append(QueryResult(
                    corpus_id=entry.corpus_id, mode=mode, k=k,
                    patterns=req.patterns,
                    counts=out[rows].astype(np.int32),
                    batched=len(requests),
                ))
        return results

    # -- result cache -------------------------------------------------------

    def _cache_get(self, key: tuple) -> Optional[QueryResult]:
        if self.cfg.result_cache_entries <= 0:
            return None
        hit = self._results.get(key)
        if hit is not None:
            self._results.move_to_end(key)
        return hit

    def _cache_put(self, key: tuple, result: QueryResult) -> None:
        if self.cfg.result_cache_entries <= 0:
            return
        self._results[key] = result
        self._results.move_to_end(key)
        while len(self._results) > self.cfg.result_cache_entries:
            self._results.popitem(last=False)

    # -- telemetry ----------------------------------------------------------

    def _observe_latency(self, t0: float, *, cached: bool) -> None:
        ms = (time.perf_counter() - t0) * 1e3
        self.rec.observe("service.request_ms", ms)
        if cached:
            self.rec.observe("service.cached_request_ms", ms)

    def stats(self) -> dict:
        """Point-in-time operational snapshot: request/dispatch counters,
        coalescing ratio, cache states — the server's ``stats`` op."""
        d = self.counters["dispatches"]
        q = self.counters["dispatched_queries"]
        return {
            **self.counters,
            "coalescing_ratio": (q / d) if d else 0.0,
            "pending": self._pending,
            "resident_corpora": list(self.corpora.ids()),
            "corpus_bytes": self.corpora.total_bytes,
            "result_cache_entries": len(self._results),
            "plan_cache": engine.plan_cache_stats(),
        }

    def slo_report(self) -> dict:
        """Latency-SLO view from the recorder's histograms: p50/p99 (ms)
        of ``service.request_ms`` plus batch-size distribution.  Empty
        when the recorder is disabled (enable it to measure — the NULL
        recorder records nothing by design)."""
        hist = self.rec.metrics.summary().get("histograms", {})
        keys = (
            "service.request_ms", "service.cached_request_ms",
            "service.batch_queries", "service.batch_patterns",
        )
        return {k: hist[k] for k in keys if k in hist}
