"""Synthetic recsys batches (CTR-style) with realistic skew: item popularity
is Zipf-distributed; labels correlate with user-history/target similarity so
models can actually learn in the examples."""

from __future__ import annotations

import numpy as np


def seq_batch(cfg, batch: int, seed: int = 0) -> dict:
    rng = np.random.RandomState(seed)
    T = cfg.seq_len
    # zipf item popularity
    items = (rng.zipf(1.3, size=(batch, T)) - 1) % cfg.item_vocab
    hist_len = rng.randint(max(1, T // 4), T + 1, size=batch)
    mask = (np.arange(T)[None, :] < hist_len[:, None]).astype(np.float32)
    target = (rng.zipf(1.3, size=batch) - 1) % cfg.item_vocab
    # label correlates with target appearing in the history
    seen = (items == target[:, None]).any(axis=1)
    p = np.where(seen, 0.7, 0.25)
    label = (rng.rand(batch) < p).astype(np.float32)
    return {
        "hist_items": items.astype(np.int32),
        "hist_cates": (items % cfg.cate_vocab).astype(np.int32),
        "hist_mask": mask,
        "target_item": target.astype(np.int32),
        "target_cate": (target % cfg.cate_vocab).astype(np.int32),
        "label": label,
    }


def dcn_batch(cfg, batch: int, seed: int = 0) -> dict:
    rng = np.random.RandomState(seed)
    vocabs = np.asarray(cfg.sparse_vocabs)
    sparse = (rng.zipf(1.2, size=(batch, cfg.n_sparse)) - 1) % vocabs[None, :]
    dense = np.log1p(rng.exponential(1.0, size=(batch, cfg.n_dense))).astype(np.float32)
    logit = dense[:, 0] - 1.0 + 0.3 * ((sparse[:, 0] % 7) == 0)
    label = (rng.rand(batch) < 1 / (1 + np.exp(-logit))).astype(np.float32)
    return {
        "dense": dense,
        "sparse": sparse.astype(np.int32),
        "label": label,
    }


def make_batch(cfg, batch: int, seed: int = 0, with_label: bool = True) -> dict:
    b = dcn_batch(cfg, batch, seed) if cfg.kind == "dcn" else seq_batch(cfg, batch, seed)
    if not with_label:
        b.pop("label")
    return b
