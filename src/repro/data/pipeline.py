"""Byte-level LM data pipeline with the paper's technique as a first-class
stage: EPSM multi-pattern blocklist filtering and fingerprint near-dup
detection run over every document before batching (DESIGN.md §4).

Documents -> [EPSM blocklist filter] -> [fingerprint dedup] -> pack into
fixed-length token sequences -> (tokens, targets) batches.  Byte-level
tokenization (vocab 256 + BOS) keeps the pipeline self-contained.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Optional, Sequence

import numpy as np

import jax

from repro.core.epsm import EPSMC_KBITS
from repro.core.multipattern import PatternSet
from repro.core.packing import fingerprint_weights, hash_blocks

BOS = 256  # byte-level vocab: 0..255 bytes + BOS
VOCAB = 257


@dataclasses.dataclass
class PipelineStats:
    docs_in: int = 0
    docs_blocked: int = 0
    docs_deduped: int = 0
    docs_out: int = 0


class FingerprintDeduper:
    """Near-duplicate detection by EPSMc-style block fingerprints.

    A document's signature is the set of its k-bit aligned-block fingerprints
    (the same MXU hash the matcher uses); documents sharing > threshold of
    their signature with a previously seen one are dropped.
    """

    def __init__(self, beta: int = 8, kbits: int = EPSMC_KBITS, threshold: float = 0.9):
        self.beta = beta
        self.kbits = kbits
        self.threshold = threshold
        self.weights = np.asarray(jax.device_get(fingerprint_weights(beta)))
        self._seen: List[frozenset] = []

    def signature(self, doc: np.ndarray) -> frozenset:
        n = (len(doc) // self.beta) * self.beta
        if n == 0:
            return frozenset()
        blocks = doc[:n].reshape(-1, self.beta).astype(np.int64)
        h = (blocks * self.weights[None, :]).sum(axis=1)
        return frozenset((h & ((1 << self.kbits) - 1)).tolist())

    def is_duplicate(self, doc: np.ndarray) -> bool:
        sig = self.signature(doc)
        if not sig:
            return False
        for prev in self._seen:
            inter = len(sig & prev)
            if inter / max(len(sig), 1) > self.threshold:
                return True
        self._seen.append(sig)
        if len(self._seen) > 4096:  # bounded memory
            self._seen = self._seen[-2048:]
        return False


class LMDataPipeline:
    def __init__(
        self,
        documents: Iterable[np.ndarray],
        seq_len: int,
        batch_size: int,
        blocklist: Optional[Sequence[bytes]] = None,
        dedup: bool = False,
        seed: int = 0,
    ):
        self.documents = iter(documents)
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.pattern_set = PatternSet(blocklist) if blocklist else None
        self.deduper = FingerprintDeduper() if dedup else None
        self.stats = PipelineStats()
        self._buffer = np.zeros(0, dtype=np.int32)

    def _clean_docs(self) -> Iterator[np.ndarray]:
        for doc in self.documents:
            self.stats.docs_in += 1
            if self.pattern_set is not None and bool(self.pattern_set.contains_any(doc)):
                self.stats.docs_blocked += 1
                continue
            if self.deduper is not None and self.deduper.is_duplicate(doc):
                self.stats.docs_deduped += 1
                continue
            self.stats.docs_out += 1
            yield doc

    def _fill(self, need: int):
        chunks = [self._buffer]
        have = len(self._buffer)
        for doc in self._clean_docs():
            tok = np.concatenate([[BOS], doc.astype(np.int32)])
            chunks.append(tok)
            have += len(tok)
            if have >= need:
                break
        self._buffer = np.concatenate(chunks) if chunks else self._buffer

    def __iter__(self):
        return self

    def __next__(self):
        need = self.batch_size * (self.seq_len + 1)
        if len(self._buffer) < need:
            self._fill(need)
        if len(self._buffer) < need:
            raise StopIteration
        flat = self._buffer[:need].reshape(self.batch_size, self.seq_len + 1)
        self._buffer = self._buffer[need:]
        return {
            "tokens": flat[:, :-1].astype(np.int32),
            "targets": flat[:, 1:].astype(np.int32),
        }
