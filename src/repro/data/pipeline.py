"""Byte-level LM data pipeline with the paper's technique as a first-class
stage: EPSM multi-pattern blocklist filtering and fingerprint near-dup
detection run over every document before batching (DESIGN.md §4, §7).

Documents -> [batched EPSM blocklist filter] -> [fingerprint dedup] -> pack
into fixed-length token sequences -> (tokens, targets) batches.  Byte-level
tokenization (vocab 256 + BOS) keeps the pipeline self-contained.

The blocklist stage is batched on device: documents are collected into a
padded (B, L) matrix (L bucketed to powers of two so jit re-traces stay
bounded) and a single engine dispatch verdicts the whole batch against every
blocklist pattern at once.  The seed pipeline dispatched once per document x
length group — pure dispatch overhead at corpus scale.  Padding rows carry
their true lengths, so patterns never match inside padding or across
document boundaries.  Documents larger than MAX_FILTER_LEN stream through
the bounded-memory scanner (repro.core.stream, DESIGN.md §9) instead of
inflating any batch: device memory stays O(MAX_FILTER_LEN) however large a
document gets.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Optional, Sequence

import numpy as np

import jax

from repro.core.epsm import EPSMC_KBITS
from repro.core.multipattern import PatternSet
from repro.core.packing import fingerprint_weights, hash_blocks

BOS = 256  # byte-level vocab: 0..255 bytes + BOS
VOCAB = 257

# documents per blocklist dispatch; the last (ragged) batch is padded up to
# this so the jitted filter sees one stable batch dimension
FILTER_BATCH = 32
# docs longer than this filter in their own singleton dispatch: one giant
# document must not inflate the whole (B, L) batch matrix to B x its bucket
MAX_FILTER_LEN = 1 << 18


def _bucket_len(n: int, floor: int = 256) -> int:
    """Round a document length up to a power-of-two bucket (bounds the
    number of distinct (B, L) shapes the jitted filter compiles for)."""
    L = floor
    while L < n:
        L *= 2
    return L


@dataclasses.dataclass
class PipelineStats:
    docs_in: int = 0
    docs_blocked: int = 0
    docs_deduped: int = 0
    docs_out: int = 0


class FingerprintDeduper:
    """Near-duplicate detection by EPSMc-style block fingerprints.

    A document's signature is the set of its k-bit aligned-block fingerprints
    (the same MXU hash the matcher uses); documents sharing > threshold of
    their signature with a previously seen one are dropped.
    """

    def __init__(self, beta: int = 8, kbits: int = EPSMC_KBITS, threshold: float = 0.9):
        self.beta = beta
        self.kbits = kbits
        self.threshold = threshold
        self.weights = np.asarray(jax.device_get(fingerprint_weights(beta)))
        self._seen: List[frozenset] = []

    def signature(self, doc: np.ndarray) -> frozenset:
        n = (len(doc) // self.beta) * self.beta
        if n == 0:
            return frozenset()
        blocks = doc[:n].reshape(-1, self.beta).astype(np.int64)
        h = (blocks * self.weights[None, :]).sum(axis=1)
        return frozenset((h & ((1 << self.kbits) - 1)).tolist())

    def is_duplicate(self, doc: np.ndarray) -> bool:
        sig = self.signature(doc)
        if not sig:
            return False
        for prev in self._seen:
            inter = len(sig & prev)
            if inter / max(len(sig), 1) > self.threshold:
                return True
        self._seen.append(sig)
        if len(self._seen) > 4096:  # bounded memory
            self._seen = self._seen[-2048:]
        return False


class LMDataPipeline:
    def __init__(
        self,
        documents: Iterable[np.ndarray],
        seq_len: int,
        batch_size: int,
        blocklist: Optional[Sequence[bytes]] = None,
        dedup: bool = False,
        seed: int = 0,
        blocklist_k: int = 0,
    ):
        """``blocklist_k`` is a Hamming mismatch budget (repro.approx): with
        k > 0 a document is dropped when any blocklist pattern occurs within
        <= k byte substitutions — obfuscated/typo'd terms are still caught.
        The batched verdict path is unchanged: the k-compiled PatternSet
        flows through the same single engine dispatch per batch."""
        self.documents = iter(documents)
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.pattern_set = (
            PatternSet(blocklist, k=blocklist_k) if blocklist else None
        )
        self.deduper = FingerprintDeduper() if dedup else None
        self.stats = PipelineStats()
        self._buffer = np.zeros(0, dtype=np.int32)
        # ONE persistent generator: _fill breaks out mid-iteration, and a
        # fresh generator per fill would drop the filtered docs still
        # buffered inside the suspended batch loop
        self._clean = self._clean_docs()

    def _filtered_batches(self) -> Iterator[List[np.ndarray]]:
        """Pull FILTER_BATCH docs, blocklist-filter them in ONE device call."""
        while True:
            docs: List[np.ndarray] = []
            for doc in self.documents:
                docs.append(np.asarray(doc, dtype=np.uint8).reshape(-1))
                if len(docs) >= FILTER_BATCH:
                    break
            if not docs:
                return
            self.stats.docs_in += len(docs)
            if self.pattern_set is None:
                yield docs
                continue
            small = [i for i, d in enumerate(docs) if len(d) <= MAX_FILTER_LEN]
            hit = np.zeros(len(docs), bool)
            if small:
                L = _bucket_len(max(len(docs[i]) for i in small))
                mat = np.zeros((FILTER_BATCH, L), np.uint8)
                lengths = np.zeros((FILTER_BATCH,), np.int32)
                for row, i in enumerate(small):
                    mat[row, : len(docs[i])] = docs[i]
                    lengths[row] = len(docs[i])
                verdict = np.asarray(
                    jax.device_get(self.pattern_set.blocked(mat, lengths))
                )
                hit[small] = verdict[: len(small)]
            for i, d in enumerate(docs):
                if len(d) > MAX_FILTER_LEN:
                    # oversize: stream through the bounded-memory scanner —
                    # O(chunk) device memory and early exit on a hit,
                    # instead of a full-size singleton dispatch that would
                    # materialize ~9 bytes/byte of index for one document
                    hit[i] = self.pattern_set.contains_any_stream(
                        d, chunk_bytes=MAX_FILTER_LEN
                    )
            kept = [d for d, h in zip(docs, hit) if not h]
            self.stats.docs_blocked += len(docs) - len(kept)
            yield kept

    def _clean_docs(self) -> Iterator[np.ndarray]:
        for batch in self._filtered_batches():
            for doc in batch:
                if self.deduper is not None and self.deduper.is_duplicate(doc):
                    self.stats.docs_deduped += 1
                    continue
                self.stats.docs_out += 1
                yield doc

    def _fill(self, need: int):
        chunks = [self._buffer]
        have = len(self._buffer)
        for doc in self._clean:
            tok = np.concatenate([[BOS], doc.astype(np.int32)])
            chunks.append(tok)
            have += len(tok)
            if have >= need:
                break
        self._buffer = np.concatenate(chunks) if chunks else self._buffer

    def __iter__(self):
        return self

    def __next__(self):
        need = self.batch_size * (self.seq_len + 1)
        if len(self._buffer) < need:
            self._fill(need)
        if len(self._buffer) < need:
            raise StopIteration
        flat = self._buffer[:need].reshape(self.batch_size, self.seq_len + 1)
        self._buffer = self._buffer[need:]
        return {
            "tokens": flat[:, :-1].astype(np.int32),
            "targets": flat[:, 1:].astype(np.int32),
        }
