"""Graph data substrate: synthetic graph generators (power-law degree) and a
REAL CSR neighbor sampler for the minibatch_lg shape (GraphSAGE fanout
sampling) — JAX has no graph library, so this IS part of the system.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # (N+1,)
    indices: np.ndarray  # (E,)
    feats: np.ndarray  # (N, d)
    labels: np.ndarray  # (N,)

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)


def synthetic_graph(
    n_nodes: int, avg_degree: int, d_feat: int, n_classes: int, seed: int = 0
) -> CSRGraph:
    """Power-law-ish degree distribution via preferential attachment lite."""
    rng = np.random.RandomState(seed)
    degs = np.minimum(
        rng.zipf(1.7, size=n_nodes) + avg_degree // 2, n_nodes - 1
    ).astype(np.int64)
    scale = (avg_degree * n_nodes) / max(degs.sum(), 1)
    degs = np.maximum((degs * scale).astype(np.int64), 1)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    indptr[1:] = np.cumsum(degs)
    indices = rng.randint(0, n_nodes, size=int(indptr[-1])).astype(np.int32)
    feats = rng.randn(n_nodes, d_feat).astype(np.float32)
    labels = rng.randint(0, n_classes, size=n_nodes).astype(np.int32)
    return CSRGraph(indptr, indices, feats, labels)


def edge_list(graph: CSRGraph) -> np.ndarray:
    """(2, E) [src, dst] from CSR (dst = row owner; messages flow src->dst)."""
    dst = np.repeat(np.arange(graph.n_nodes, dtype=np.int32),
                    np.diff(graph.indptr).astype(np.int64))
    return np.stack([graph.indices.astype(np.int32), dst])


def sample_subgraph(
    graph: CSRGraph,
    seeds: np.ndarray,
    fanout: Tuple[int, ...],
    seed: int = 0,
) -> dict:
    """GraphSAGE-style layered uniform neighbor sampling.

    Returns a PADDED subgraph (fixed shapes for jit): nodes are
    [seeds | hop1 | hop2 ...], each hop padded to seeds * prod(fanout so far);
    edges point sampled-neighbor -> parent.  Padding uses node 0 with a mask.
    """
    rng = np.random.RandomState(seed)
    frontier = seeds.astype(np.int64)
    all_nodes = [frontier]
    srcs, dsts = [], []
    offset = 0  # index of the frontier inside the node table
    next_offset = len(frontier)
    for f in fanout:
        pad_n = len(frontier) * f
        nbrs = np.zeros(pad_n, dtype=np.int64)
        mask = np.zeros(pad_n, dtype=bool)
        for i, node in enumerate(frontier):
            lo, hi = graph.indptr[node], graph.indptr[node + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, deg)
            picks = graph.indices[lo + rng.choice(deg, size=take, replace=deg < f)]
            nbrs[i * f : i * f + take] = picks[:take]
            mask[i * f : i * f + take] = True
        # edges: sampled neighbor (child, new slot) -> parent (frontier slot)
        child_slots = next_offset + np.arange(pad_n)
        parent_slots = offset + np.repeat(np.arange(len(frontier)), f)
        keep = mask
        srcs.append(child_slots[keep])
        dsts.append(parent_slots[keep])
        all_nodes.append(nbrs)
        offset = next_offset
        next_offset += pad_n
        frontier = nbrs

    node_ids = np.concatenate(all_nodes)
    src = np.concatenate(srcs).astype(np.int32) if srcs else np.zeros(0, np.int32)
    dst = np.concatenate(dsts).astype(np.int32) if dsts else np.zeros(0, np.int32)
    max_edges = sum(len(seeds) * int(np.prod(fanout[: i + 1])) for i in range(len(fanout)))
    e = len(src)
    src_p = np.zeros(max_edges, np.int32)
    dst_p = np.zeros(max_edges, np.int32)
    edge_mask = np.zeros(max_edges, np.float32)
    src_p[:e], dst_p[:e], edge_mask[:e] = src, dst, 1.0
    label_mask = np.zeros(len(node_ids), np.float32)
    label_mask[: len(seeds)] = 1.0  # loss only on seed nodes
    return {
        "nodes": graph.feats[node_ids],
        "edges": np.stack([src_p, dst_p]),
        "edge_mask": edge_mask,
        "labels": graph.labels[node_ids],
        "label_mask": label_mask,
        "node_ids": node_ids,
    }


def batched_molecules(
    n_graphs: int, nodes_per: int, edges_per: int, d_feat: int, d_edge: int, seed: int = 0
) -> dict:
    """Block-diagonal batch of small molecule-like graphs + scalar targets."""
    rng = np.random.RandomState(seed)
    N, E = n_graphs * nodes_per, n_graphs * edges_per
    src = rng.randint(0, nodes_per, size=E).astype(np.int32)
    dst = rng.randint(0, nodes_per, size=E).astype(np.int32)
    block = np.repeat(np.arange(n_graphs, dtype=np.int32), edges_per) * nodes_per
    return {
        "nodes": rng.randn(N, d_feat).astype(np.float32),
        "edges": np.stack([src + block, dst + block]),
        "edge_feats": rng.randn(E, d_edge).astype(np.float32),
        "graph_ids": np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per),
        "graph_targets": rng.randn(n_graphs).astype(np.float32),
    }
