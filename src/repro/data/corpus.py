"""Synthetic corpora mirroring the paper's three benchmark texts
(Section 4: a genome sequence, a protein sequence, a natural-language text,
4MB each, from the SMART tool).  Deterministic given a seed.
"""

from __future__ import annotations

from typing import Iterator, List

import numpy as np

GENOME_ALPHABET = b"ACGT"
PROTEIN_ALPHABET = b"ACDEFGHIKLMNPQRSTVWY"

# a small Zipf-weighted lexicon for english-like text
_WORDS = (
    "the of and to a in that is was he for it with as his on be at by i this "
    "had not are but from or have an they which one you were her all she "
    "there would their we him been has when who will more no if out so said "
    "what up its about into than them can only other new some could time "
    "these two may then do first any my now such like our over man me even "
    "most made after also did many before must through back years where much "
    "your way well down should because each just those people mr how too "
    "little state good very make world still own see men work long get here "
    "between both life being under never day same another know while last "
    "might us great old year off come since against go came right used take "
    "three states himself few house use during without again place american "
    "around however home small found mrs thought went say part once general "
    "high upon school every don does got united left number course war "
    "until always away something fact though water less public put think "
    "almost hand enough far took head yet government system better set told "
    "nothing night end why called didn eyes find going look asked later "
    "knew point next program city business give group toward young days let "
    "room within children side social given order often national"
).split()


def genome(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    alpha = np.frombuffer(GENOME_ALPHABET, dtype=np.uint8)
    return alpha[rng.randint(0, len(alpha), size=n)]


def protein(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    alpha = np.frombuffer(PROTEIN_ALPHABET, dtype=np.uint8)
    return alpha[rng.randint(0, len(alpha), size=n)]


def english(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    ranks = np.arange(1, len(_WORDS) + 1, dtype=np.float64)
    probs = (1.0 / ranks) / (1.0 / ranks).sum()  # Zipf
    out = bytearray()
    while len(out) < n:
        w = _WORDS[rng.choice(len(_WORDS), p=probs)]
        out += w.encode()
        out += b" " if rng.rand() > 0.12 else b". "
    return np.frombuffer(bytes(out[:n]), dtype=np.uint8)


CORPORA = {"genome": genome, "protein": protein, "english": english}


def make_corpus(name: str, n: int, seed: int = 0) -> np.ndarray:
    return CORPORA[name](n, seed)


def documents(
    name: str, n_docs: int, doc_len: int = 2048, seed: int = 0
) -> Iterator[np.ndarray]:
    """Stream of documents (uint8 arrays) from one corpus family."""
    for i in range(n_docs):
        yield make_corpus(name, doc_len, seed=seed * 100003 + i)


def extract_patterns(text: np.ndarray, m: int, count: int, seed: int = 0) -> np.ndarray:
    """Random pattern set extracted from the text (the paper's methodology:
    'sets of patterns of fixed length m randomly extracted from the text')."""
    rng = np.random.RandomState(seed)
    starts = rng.randint(0, len(text) - m + 1, size=count)
    return np.stack([text[s : s + m] for s in starts])
