"""Mesh construction.  A FUNCTION, not a module-level constant — importing
this module never touches jax device state."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods when multi_pod (512 chips total)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(axis_names=("data", "model")):
    """Mesh over whatever devices this process actually has (tests/examples).
    Puts all devices on the first axis."""
    n = len(jax.devices())
    shape = (n,) + (1,) * (len(axis_names) - 1)
    return jax.make_mesh(shape, axis_names)
