"""Mesh construction and the shard-stream cluster entrypoint.  FUNCTIONS,
not module-level constants — importing this module never touches jax device
state.  All mesh building goes through ``repro.dist.compat.make_mesh`` so
the same code runs on the 0.4.x line (no ``jax.make_mesh``) and on latest.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.dist import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods when multi_pod (512 chips total)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_local_mesh(axis_names=("data", "model")):
    """Mesh over whatever devices this process actually has (tests/examples).
    Puts all devices on the first axis."""
    n = len(jax.devices())
    shape = (n,) + (1,) * (len(axis_names) - 1)
    return compat.make_mesh(shape, axis_names)


# (the sharded-stream count merge builds its own 1-D device mesh inline in
# dist.compat.sum_across_devices — only the devices that actually hold
# shard partials belong on the axis, which varies per scan)

# jax.process_count() itself initializes the local backend, after which
# jax.distributed.initialize refuses to run — so idempotency is tracked here
# instead of queried from jax.
_CLUSTER_JOINED = False


def init_stream_cluster(
    coordinator: Optional[str] = None,
    num_processes: Optional[int] = None,
    process_id: Optional[int] = None,
) -> Tuple[int, int]:
    """Shard-stream entrypoint: join (or skip) a jax.distributed cluster.

    Returns (process_index, process_count).  With ``num_processes`` None or
    1 this is a no-op single-process run — the same ShardedStreamScanner
    code path then merges locally, so examples and tests need no mode
    switch.  Idempotent: a second call just reports the cluster shape.
    MUST run before any other jax call when joining a real cluster."""
    global _CLUSTER_JOINED
    if num_processes is not None and int(num_processes) > 1 and not _CLUSTER_JOINED:
        try:
            # the CPU backend only speaks cross-process collectives through
            # gloo; a no-op (and absent flag) on TPU/GPU and old jax
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
        except AttributeError:
            pass
        jax.distributed.initialize(
            coordinator_address=coordinator,
            num_processes=int(num_processes),
            process_id=None if process_id is None else int(process_id),
        )
        _CLUSTER_JOINED = True
    return jax.process_index(), jax.process_count()
