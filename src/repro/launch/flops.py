"""Analytic MODEL_FLOPS per (arch, shape): the useful-work reference for the
roofline's MODEL_FLOPS / HLO_FLOPS ratio (DESIGN.md §7).

Conventions:
  * LM train:    6*N*D + 3*L*B*S^2*H*hd      (causal attention ~ half dense)
  * LM prefill:  2*N*D + 1*L*B*S^2*H*hd
  * LM decode:   2*N*B + 4*L*B*S*H*hd        (full KV cache read, qk + pv)
    with N = active (top-k MoE) non-embedding-gather params: the input
    embedding is a gather (0 FLOPs); the unembed matmul stays.
  * GNN fwd:     L*(6*E*d^2 + 4*N*d^2) + 2*N*d_feat*d + head; train = 3x fwd
  * RecSys:      per-model interaction+tower matmul counts; train = 3x fwd.
    Embedding lookups are gathers: 0 FLOPs (they show up in the memory term).
"""

from __future__ import annotations

from repro.configs.registry import get_arch
from repro.configs.shapes import ShapeCell


def _lm_flops(cfg, kind: str, B: int, S: int) -> float:
    V, d = cfg.vocab, cfg.d_model
    # input embedding is a gather (0 FLOPs); tied models reuse the same matrix
    # as the (FLOP-bearing) unembed matmul, so only untied models subtract it
    emb = 0 if cfg.tie_embeddings else V * d
    N = cfg.active_param_count() - emb
    L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
    if kind == "lm_train":
        return 6.0 * N * B * S + 6.0 * L * B * S * S * H * hd
    if kind == "lm_prefill":
        return 2.0 * N * B * S + 2.0 * L * B * S * S * H * hd
    if kind == "lm_decode":
        return 2.0 * N * B + 4.0 * L * B * S * H * hd
    raise ValueError(kind)


def _gnn_flops(cfg, meta: dict, kind: str) -> float:
    d = cfg.d_hidden
    if kind == "gnn_batched":
        N = meta["n_graphs"] * meta["nodes_per_graph"]
        E = meta["n_graphs"] * meta["edges_per_graph"]
    elif kind == "gnn_sampled":
        N, E = meta["sub_nodes"], meta["sub_edges"]
    else:
        N, E = meta["n_nodes"], meta["n_edges"]
    fwd = cfg.n_layers * (6.0 * E * d * d + 4.0 * N * d * d)
    fwd += 2.0 * N * cfg.d_feat * d
    if cfg.readout == "node":
        fwd += 2.0 * N * d * cfg.n_classes
    else:
        fwd += 2.0 * N * d * d
    return 3.0 * fwd  # all gnn shapes are training cells


def _mlp_flops(dims, B):
    f = 0.0
    for i in range(len(dims) - 1):
        f += 2.0 * B * dims[i] * dims[i + 1]
    return f


def _recsys_fwd_flops(cfg, B: int) -> float:
    d = 2 * cfg.embed_dim  # pair embed width for sequence models
    T = cfg.seq_len
    if cfg.kind == "din":
        att = _mlp_flops([4 * d, *cfg.attn_mlp, 1], B * T)  # per-position MLP
        pool = 2.0 * B * T * d
        tower = _mlp_flops([3 * d, *cfg.mlp, 1], B)
        return att + pool + tower
    if cfg.kind == "dien":
        dh = cfg.gru_dim
        gru1 = 3 * 2.0 * B * T * (d + dh) * dh
        gru2 = 3 * 2.0 * B * T * (dh + dh) * dh
        att = 2.0 * B * T * dh * d
        tower = _mlp_flops([d + dh, *cfg.mlp, 1], B)
        return gru1 + gru2 + att + tower
    if cfg.kind == "bst":
        T1 = T + 1
        proj = 4 * 2.0 * B * T1 * d * d
        attn = 2 * 2.0 * B * T1 * T1 * d
        ffn = 2 * 2.0 * B * T1 * d * 4 * d
        tower = _mlp_flops([T1 * d, *cfg.mlp, 1], B)
        return cfg.n_blocks * (proj + attn + ffn) + tower
    if cfg.kind == "dcn":
        x0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
        cross = cfg.n_cross_layers * 2.0 * B * x0 * x0
        tower = _mlp_flops([x0, *cfg.mlp, 1], B)
        return cross + tower
    raise ValueError(cfg.kind)


def model_flops(arch_id: str, shape_id: str) -> float:
    spec = get_arch(arch_id)
    cell: ShapeCell = spec.shapes[shape_id]
    cfg = spec.make_config(shape_id)
    if spec.family == "lm":
        return _lm_flops(cfg, cell.kind, cell.meta["batch"], cell.meta["seq"])
    if spec.family == "gnn":
        return _gnn_flops(cfg, cell.meta, cell.kind)
    B = cell.meta.get("n_candidates", cell.meta["batch"])
    fwd = _recsys_fwd_flops(cfg, B)
    return 3.0 * fwd if cell.kind == "rs_train" else fwd
