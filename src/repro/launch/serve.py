"""Serving launcher: batched KV-cache decoding with EPSM stop-strings.

    PYTHONPATH=src python -m repro.launch.serve --requests 4 --max-new 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import reduced_config
from repro.data.pipeline import VOCAB
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        reduced_config("smollm-135m"), vocab=VOCAB,
        q_chunk=64, kv_chunk=64, ce_chunk=64,
    )
    params = tf.init_params(jax.random.key(0), cfg)
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        (params, _), step = ckpt.restore((params, adamw_init(params)), args.ckpt_dir)
        print(f"restored step {step}")

    eng = ServeEngine(params, cfg, max_len=256)
    prompts = [f"request {i:02d} says ".encode() for i in range(args.requests)]
    t0 = time.perf_counter()
    results = eng.generate(
        prompts, max_new_tokens=args.max_new, temperature=0.7,
        stop_strings=[b". ", b"\n"],
    )
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in results)
    print(f"served {len(prompts)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    for p, r in zip(prompts, results):
        print(f"  {p!r} -> {r.text[:40]!r} stopped_by={r.stopped_by!r}")


if __name__ == "__main__":
    main()
