"""Production training launcher: sharded LM training on a mesh.

On real hardware this runs under the 16x16 (or 2x16x16) production mesh;
locally it builds a mesh over available devices.  Wires together: config
registry -> sharded train step (launch/cells.py machinery) -> EPSM-filtered
data pipeline -> checkpointing + watchdog.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 50 --reduced --seq 128 --batch 8
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced_config
from repro.data import corpus
from repro.data.pipeline import LMDataPipeline, VOCAB
from repro.dist import sharding as sh
from repro.dist.fault_tolerance import StepWatchdog
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import transformer as tf
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (requires 256 devices)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()

    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        mesh = make_local_mesh(("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    if args.reduced:
        cfg = dataclasses.replace(
            reduced_config(args.arch), vocab=VOCAB,
            q_chunk=args.seq, kv_chunk=args.seq, ce_chunk=args.seq,
        )
    else:
        cfg = dataclasses.replace(get_arch(args.arch).make_config(), vocab=VOCAB)

    pspecs = sh.lm_param_specs(cfg, mesh)
    constrain = sh.make_constrain(
        mesh, sh.lm_activation_table(cfg, mesh, "lm_train", args.batch)
    )
    param_sh = sh.tree_to_shardings(mesh, pspecs)
    opt_cfg = AdamWConfig(peak_lr=3e-3, warmup_steps=20, total_steps=args.steps)

    with mesh:
        params = jax.jit(
            lambda k: tf.init_params(k, cfg), out_shardings=param_sh
        )(jax.random.key(0))
        opt_state = jax.jit(
            adamw_init,
            out_shardings=sh.tree_to_shardings(mesh, sh.opt_state_specs(pspecs)),
        )(params)

        start = 0
        if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
            (params, opt_state), start = ckpt.restore(
                (params, opt_state), args.ckpt_dir,
                shardings=(param_sh, sh.tree_to_shardings(mesh, sh.opt_state_specs(pspecs))),
            )
            print(f"resumed from step {start}")

        @jax.jit
        def step_fn(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: tf.train_loss(p, cfg, batch, constrain)
            )(params)
            new_p, new_s, metrics = adamw_update(grads, opt_state, params, opt_cfg)
            metrics["loss"] = loss
            return new_p, new_s, metrics

        docs = corpus.documents("english", 100_000, doc_len=4096, seed=0)
        pipe = LMDataPipeline(docs, seq_len=args.seq, batch_size=args.batch,
                              blocklist=[b"FORBIDDEN"], dedup=False)
        wd = StepWatchdog(policy="log")
        bspec = sh.tree_to_shardings(
            mesh, sh.lm_batch_specs("lm_train", mesh, args.batch)
        )
        for step, batch in zip(range(start, args.steps), pipe):
            wd.start_step(step)
            batch = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(jnp.asarray(x), s), batch, bspec
            )
            params, opt_state, m = step_fn(params, opt_state, batch)
            wd.end_step()
            if step % 10 == 0:
                print(f"step {step}: loss={float(m['loss']):.4f}")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                ckpt.save((params, opt_state), args.ckpt_dir, step + 1, async_=True)
    print("done;", f"{len(wd.events)} straggler events")


if __name__ == "__main__":
    main()
