import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import — jax locks the device
# count at first init.  REPRO_DRYRUN_DEVICES overrides for small local tests.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=" + os.environ["REPRO_DRYRUN_DEVICES"]
    )

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro.analysis import roofline  # noqa: E402
from repro.analysis.hlo_collectives import collective_sites, collective_stats  # noqa: E402
from repro.analysis.jaxpr_cost import step_cost  # noqa: E402
from repro.configs.registry import all_cells, get_arch  # noqa: E402
from repro.dist.compat import cost_analysis_dict  # noqa: E402
from repro.launch.cells import build_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def _analytic_shard_bytes(args, shardings) -> int:
    """Per-device bytes of the (sharded) inputs, from NamedSharding math."""
    total = 0
    for sds, sh in zip(
        jax.tree_util.tree_leaves(args), jax.tree_util.tree_leaves(shardings)
    ):
        shard_shape = sh.shard_shape(sds.shape)
        total += int(np.prod(shard_shape)) * sds.dtype.itemsize
    return total


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # CPU backend may not implement it
        return {"unavailable": str(e)[:200]}
    out = {}
    for attr in dir(ma):
        if attr.startswith("_"):
            continue
        try:
            v = getattr(ma, attr)
        except Exception:
            continue
        if isinstance(v, (int, float)):
            out[attr] = v
    return out or {"repr": repr(ma)[:500]}


def run_cell(arch_id: str, shape_id: str, multi_pod: bool, outdir: Path, *, mesh=None, sites: bool = False, strategy: str = "default") -> dict:
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    mesh_tag = "x".join(str(s) for s in mesh.devices.shape)
    cell = build_cell(arch_id, shape_id, mesh, strategy)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = cost_analysis_dict(compiled)
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    # XLA cost analysis counts while/scan bodies once (verified; see
    # analysis/jaxpr_cost.py) — use the scan-aware jaxpr walker instead.
    est = step_cost(cell.step_fn, *cell.args)
    flops = est["mxu_flops"] / n_chips  # global -> per-chip (work is sharded)
    vpu = est["vpu_flops"] / n_chips
    bytes_accessed = est["bytes"] / n_chips
    hlo = compiled.as_text()
    coll_stats = collective_stats(hlo)
    coll_bytes = roofline.collective_bytes(coll_stats)
    site_rows = collective_sites(hlo) if sites else None
    mem = _memory_analysis_dict(compiled)
    terms = roofline.roofline_terms(flops, bytes_accessed, coll_bytes, vpu)

    record = {
        "arch": arch_id,
        "shape": shape_id,
        "kind": cell.kind,
        "mesh": mesh_tag,
        "n_chips": n_chips,
        "multi_pod": multi_pod,
        "strategy": strategy,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops_per_chip": flops,
        "vpu_flops_per_chip": vpu,
        "hlo_bytes_per_chip": bytes_accessed,
        "raw_cost_analysis_flops": raw_flops,
        "raw_cost_analysis_bytes": raw_bytes,
        "est_flops_global": est["flops"],
        "est_bytes_global": est["bytes"],
        "collective_bytes_per_chip": coll_bytes,
        "collectives": coll_stats,
        "collective_sites": site_rows,
        "memory_analysis": mem,
        "arg_bytes_per_chip": _analytic_shard_bytes(cell.args, cell.in_shardings),
        "model_flops_global": cell.model_flops,
        "model_flops_per_chip": cell.model_flops / n_chips,
        "useful_flops_ratio": (cell.model_flops / est["mxu_flops"]) if est["mxu_flops"] else None,
        "roofline": terms,
    }
    outdir.mkdir(parents=True, exist_ok=True)
    fname = f"{arch_id.replace('/', '_')}__{shape_id}__{mesh_tag}.json"
    (outdir / fname).write_text(json.dumps(record, indent=1))
    print(roofline.summarize(record), f"(compile {t_compile:.1f}s)", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser(description="Multi-pod dry-run: lower+compile every cell")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true", help="run every assigned cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--sites", action="store_true", help="attribute collective bytes to op_names")
    ap.add_argument("--strategy", default="default", help="sharding strategy (tp_sp|zero_dp|nodes_sharded|nodes_replicated)")
    args = ap.parse_args()

    if args.list:
        for a, s in all_cells():
            print(a, s)
        return

    outdir = Path(args.out)
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch_id, shape_id in cells:
        if arch_id is None or shape_id is None:
            raise SystemExit("need --arch and --shape (or --all)")
        for mp in meshes:
            tag = "2x16x16" if mp else "16x16"
            fname = outdir / f"{arch_id}__{shape_id}__{tag}.json"
            if args.skip_existing and fname.exists():
                print("skip", fname.name, flush=True)
                continue
            try:
                run_cell(arch_id, shape_id, mp, outdir, sites=args.sites, strategy=args.strategy)
            except Exception as e:
                failures.append((arch_id, shape_id, tag, repr(e)))
                print(f"FAIL {arch_id}/{shape_id}@{tag}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", *f)
        raise SystemExit(1)
    print("\nall requested cells compiled OK")


if __name__ == "__main__":
    main()
