"""Dry-run cell builder: for every assigned (arch x shape x mesh) produce the
step function, ShapeDtypeStruct inputs (no allocation) and in/out shardings,
ready for jit(...).lower(...).compile().
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_arch
from repro.configs.shapes import pad_to
from repro.dist import sharding as sh
from repro.launch.flops import model_flops
from repro.models import gnn as gnn_mod
from repro.models import recsys as rs_mod
from repro.models import transformer as tf_mod
from repro.train.optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_id: str
    kind: str
    step_fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    model_flops: float
    notes: str = ""


def _shardify(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _sds_like(shape_tree):
    return jax.tree_util.tree_map(lambda s: SDS(s.shape, s.dtype), shape_tree)


def _opt_sds(param_sds):
    return jax.eval_shape(adamw_init, param_sds)


_METRIC_SPECS = {"grad_norm": P(), "lr": P()}


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _build_lm(arch_id, shape_id, mesh, cell_meta, kind, strategy="tp_sp"):
    spec = get_arch(arch_id)
    cfg = spec.make_config(shape_id)
    B, S = cell_meta["batch"], cell_meta["seq"]
    pspecs = sh.lm_param_specs(cfg, mesh, strategy)
    act_table = sh.lm_activation_table(cfg, mesh, kind, B, strategy)
    constrain = sh.make_constrain(mesh, act_table)
    param_sds = _sds_like(tf_mod.param_shapes(cfg))
    bspecs = sh.lm_batch_specs(kind, mesh, B, strategy)

    if kind == "lm_train":
        opt_cfg = AdamWConfig()

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: tf_mod.train_loss(p, cfg, batch, constrain)
            )(params)
            new_p, new_s, metrics = adamw_update(grads, opt_state, params, opt_cfg)
            return loss, new_p, new_s, metrics

        batch_sds = {
            "tokens": SDS((B, S), jnp.int32),
            "targets": SDS((B, S), jnp.int32),
        }
        opt_sds = _opt_sds(param_sds)
        opt_specs = sh.opt_state_specs(pspecs)
        args = (param_sds, opt_sds, batch_sds)
        in_sh = (
            _shardify(mesh, pspecs),
            _shardify(mesh, opt_specs),
            _shardify(mesh, bspecs),
        )
        out_sh = (
            NamedSharding(mesh, P()),
            _shardify(mesh, pspecs),
            _shardify(mesh, opt_specs),
            _shardify(mesh, _METRIC_SPECS),
        )
        return step, args, in_sh, out_sh

    dp = sh.dp_axes(mesh)
    bdp = dp if B % sh.axis_size(mesh, dp) == 0 else None
    vocab_tp = "model" if cfg.vocab % sh.axis_size(mesh, "model") == 0 else None
    cache_shape = (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.head_dim)
    cache_dt = jnp.bfloat16

    if kind == "lm_prefill":

        def step(params, tokens):
            return tf_mod.prefill(params, cfg, tokens, constrain)

        args = (param_sds, SDS((B, S), jnp.int32))
        in_sh = (_shardify(mesh, pspecs), NamedSharding(mesh, bspecs["tokens"]))
        cache_spec = NamedSharding(mesh, P(None, bdp, "model", None, None))
        out_sh = (
            NamedSharding(mesh, P(bdp, vocab_tp)),
            cache_spec,
            cache_spec,
        )
        return step, args, in_sh, out_sh

    if kind == "lm_decode":
        if strategy == "kv_int8":
            # int8 KV cache (per-position scales): ~1.94x smaller cache reads
            # for the memory-bound long-context decode cells (§Perf)
            def step(params, token, pos, kcache, vcache):
                return tf_mod.decode_step_q8(
                    params, cfg, token, pos, kcache, vcache, constrain
                )

            cache_sds = {
                "q": SDS(cache_shape, jnp.int8),
                "scale": SDS(cache_shape[:-1], jnp.float32),
            }
            cspec_q = NamedSharding(mesh, bspecs["kcache"])
            cspec_s = NamedSharding(mesh, P(*bspecs["kcache"][:-1]))
            cache_sh = {"q": cspec_q, "scale": cspec_s}
            args = (
                param_sds,
                SDS((B, 1), jnp.int32),
                SDS((), jnp.int32),
                cache_sds,
                cache_sds,
            )
            in_sh = (
                _shardify(mesh, pspecs),
                NamedSharding(mesh, bspecs["token"]),
                NamedSharding(mesh, P()),
                cache_sh,
                cache_sh,
            )
            out_sh = (NamedSharding(mesh, P(bdp, vocab_tp)), cache_sh, cache_sh)
            return step, args, in_sh, out_sh

        def step(params, token, pos, kcache, vcache):
            return tf_mod.decode_step(params, cfg, token, pos, kcache, vcache, constrain)

        args = (
            param_sds,
            SDS((B, 1), jnp.int32),
            SDS((), jnp.int32),
            SDS(cache_shape, cache_dt),
            SDS(cache_shape, cache_dt),
        )
        cspec = NamedSharding(mesh, bspecs["kcache"])
        in_sh = (
            _shardify(mesh, pspecs),
            NamedSharding(mesh, bspecs["token"]),
            NamedSharding(mesh, P()),
            cspec,
            cspec,
        )
        out_sh = (NamedSharding(mesh, P(bdp, vocab_tp)), cspec, cspec)
        return step, args, in_sh, out_sh

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _build_gnn(arch_id, shape_id, mesh, meta, kind, strategy="nodes_sharded"):
    spec = get_arch(arch_id)
    cfg = spec.make_config(shape_id)
    if "+bf16" in strategy:
        # bf16 node/edge states (norms still reduce in fp32): halves every
        # gather/scatter collective of the message-passing loop (§Perf)
        cfg = dataclasses.replace(cfg, dtype="bfloat16", param_dtype="bfloat16")
        strategy = strategy.replace("+bf16", "")
    ndev = sh.axis_size(mesh, sh.all_axes(mesh))

    if kind == "gnn_batched":
        N = pad_to(meta["n_graphs"] * meta["nodes_per_graph"], ndev)
        E = pad_to(meta["n_graphs"] * meta["edges_per_graph"], ndev)
        n_graphs = meta["n_graphs"]
        batch_sds = {
            "nodes": SDS((N, meta["d_feat"]), jnp.float32),
            "edges": SDS((2, E), jnp.int32),
            "edge_feats": SDS((E, meta["d_edge_feat"]), jnp.float32),
            "graph_ids": SDS((N,), jnp.int32),
            "graph_targets": SDS((n_graphs,), jnp.float32),
        }
        loss_fn = functools.partial(gnn_mod.train_loss, n_graphs=n_graphs)
    else:
        if kind == "gnn_sampled":
            N, E = pad_to(meta["sub_nodes"], ndev), pad_to(meta["sub_edges"], ndev)
        else:
            N, E = pad_to(meta["n_nodes"], ndev), pad_to(meta["n_edges"], ndev)
        batch_sds = {
            "nodes": SDS((N, meta["d_feat"]), jnp.float32),
            "edges": SDS((2, E), jnp.int32),
            "labels": SDS((N,), jnp.int32),
            "label_mask": SDS((N,), jnp.float32),
        }
        loss_fn = gnn_mod.train_loss

    constrain = sh.make_constrain(mesh, sh.gnn_activation_table(mesh, strategy))
    param_sds = _sds_like(gnn_mod.param_shapes(cfg))
    pspecs = sh.gnn_param_specs(param_sds)
    opt_cfg = AdamWConfig()

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, constrain=constrain)
        )(params)
        new_p, new_s, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        return loss, new_p, new_s, metrics

    opt_sds = _opt_sds(param_sds)
    opt_specs = sh.opt_state_specs(pspecs)
    bspecs = sh.gnn_batch_specs(mesh, batch_sds)
    args = (param_sds, opt_sds, batch_sds)
    in_sh = (
        _shardify(mesh, pspecs),
        _shardify(mesh, opt_specs),
        _shardify(mesh, bspecs),
    )
    out_sh = (
        NamedSharding(mesh, P()),
        _shardify(mesh, pspecs),
        _shardify(mesh, opt_specs),
        _shardify(mesh, _METRIC_SPECS),
    )
    return step, args, in_sh, out_sh


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _rs_batch_sds(cfg, B, with_label=True):
    if cfg.kind == "dcn":
        d = {
            "dense": SDS((B, cfg.n_dense), jnp.float32),
            "sparse": SDS((B, cfg.n_sparse), jnp.int32),
        }
    else:
        T = cfg.seq_len
        d = {
            "hist_items": SDS((B, T), jnp.int32),
            "hist_cates": SDS((B, T), jnp.int32),
            "hist_mask": SDS((B, T), jnp.float32),
            "target_item": SDS((B,), jnp.int32),
            "target_cate": SDS((B,), jnp.int32),
        }
    if with_label:
        d["label"] = SDS((B,), jnp.float32)
    return d


def _build_recsys(arch_id, shape_id, mesh, meta, kind):
    spec = get_arch(arch_id)
    cfg = spec.make_config(shape_id)
    param_sds = _sds_like(rs_mod.param_shapes(cfg))
    pspecs = sh.recsys_param_specs(cfg, mesh, param_sds)
    dp = sh.dp_axes(mesh)

    if kind == "rs_train":
        B = meta["batch"]
        opt_cfg = AdamWConfig()

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: rs_mod.train_loss(p, cfg, batch)
            )(params)
            new_p, new_s, metrics = adamw_update(grads, opt_state, params, opt_cfg)
            return loss, new_p, new_s, metrics

        batch_sds = _rs_batch_sds(cfg, B)
        opt_sds = _opt_sds(param_sds)
        opt_specs = sh.opt_state_specs(pspecs)
        bspecs = sh.recsys_batch_specs(mesh, batch_sds)
        args = (param_sds, opt_sds, batch_sds)
        in_sh = (
            _shardify(mesh, pspecs),
            _shardify(mesh, opt_specs),
            _shardify(mesh, bspecs),
        )
        out_sh = (
            NamedSharding(mesh, P()),
            _shardify(mesh, pspecs),
            _shardify(mesh, opt_specs),
            _shardify(mesh, _METRIC_SPECS),
        )
        return step, args, in_sh, out_sh

    if kind == "rs_serve":
        B = meta["batch"]

        def step(params, batch):
            return rs_mod.serve_scores(params, cfg, batch)

        batch_sds = _rs_batch_sds(cfg, B, with_label=False)
        bspecs = sh.recsys_batch_specs(mesh, batch_sds)
        args = (param_sds, batch_sds)
        in_sh = (_shardify(mesh, pspecs), _shardify(mesh, bspecs))
        out_sh = NamedSharding(mesh, P(dp))
        return step, args, in_sh, out_sh

    if kind == "rs_retrieval":
        C = meta["n_candidates"]

        def step(params, user_batch, candidates):
            return rs_mod.retrieval_scores(params, cfg, user_batch, candidates)

        user_sds = _rs_batch_sds(cfg, 1, with_label=False)
        if cfg.kind == "dcn":
            cand_sds = SDS((C, cfg.n_sparse), jnp.int32)
        else:
            cand_sds = SDS((C,), jnp.int32)
        user_specs = jax.tree_util.tree_map(lambda _: P(), user_sds)
        cand_spec = P(dp, *([None] * (cand_sds.ndim - 1)))
        args = (param_sds, user_sds, cand_sds)
        in_sh = (
            _shardify(mesh, pspecs),
            _shardify(mesh, user_specs),
            NamedSharding(mesh, cand_spec),
        )
        out_sh = NamedSharding(mesh, P(dp))
        return step, args, in_sh, out_sh

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_id: str, mesh: Mesh, strategy: str = "default") -> Cell:
    spec = get_arch(arch_id)
    cell_meta = spec.shapes[shape_id]
    kind = cell_meta.kind
    if spec.family == "lm":
        strat = "tp_sp" if strategy == "default" else strategy
        if strat == "kv_int8":
            pass  # decode-only variant; activation/param specs stay tp_sp
        step, args, in_sh, out_sh = _build_lm(arch_id, shape_id, mesh, cell_meta.meta, kind, strat)
    elif spec.family == "gnn":
        strat = "nodes_sharded" if strategy == "default" else strategy
        step, args, in_sh, out_sh = _build_gnn(arch_id, shape_id, mesh, cell_meta.meta, kind, strat)
    else:
        step, args, in_sh, out_sh = _build_recsys(arch_id, shape_id, mesh, cell_meta.meta, kind)
    return Cell(
        arch_id=arch_id,
        shape_id=shape_id,
        kind=kind,
        step_fn=step,
        args=args,
        in_shardings=in_sh,
        out_shardings=out_sh,
        model_flops=model_flops(arch_id, shape_id),
    )
