"""Shared-text batched multi-pattern matching engine (the repo's hot path).

The paper's packed matcher amortizes one SSE word op over 16 positions; its
sequel (Faro & Kulekci, SPIRE 2012 — paper ref [10]) amortizes one pass over
the text across many patterns.  This module is that second amortization done
TPU-style, as an explicit two-phase design (DESIGN.md §7):

  * :class:`TextIndex` — everything that depends only on the text, computed
    ONCE per batch of texts: the packed u32 4-gram view (EPSMb's anchor
    registers) and the aligned beta-block fingerprints (EPSMc's wscrc
    stream).  Batchable over a leading (B, n) dimension with per-row true
    lengths, so ragged documents ride in one padded matrix.

  * :class:`PatternPlan` — everything that depends only on the patterns,
    compiled once per equal-length group: the stacked packed anchor words
    (EPSMb) and a union 2^k lookup table over all patterns' block
    fingerprints.  Payloads scale with the group: pattern-id / bitmask LUTs
    at flat P, fingerprint-sorted CSR slot tables (plus an optional packed
    Aho-Corasick fallback) at dictionary scale (DESIGN.md §14).  The plan
    for a group of P patterns answers all P in one probe of the shared text
    work; ``compile_patterns(..., canonical=True)`` additionally quantizes
    the plan statics so the serving query plane can coalesce arbitrary
    unions onto one jitted executable (DESIGN.md §15).

  * :func:`match_many` joins them: ``bool[B, P, n]`` match-start masks for
    P patterns x B texts in ONE device dispatch (one jit call, no host loop
    over patterns, groups, or batch elements).  :func:`count_many` /
    :func:`any_many` are the reduced variants the data pipeline and serving
    engine actually consume — they never materialize the (B, P, n) mask.

Why this beats the vmapped per-pattern scan (the previous multipattern path):
XLA already shares the text packing across a vmap, but the per-position
compare work still scales as O(P * n).  The engine's union LUT makes the
per-position filter O(n) *independent of P* — one fingerprint probe answers
"could ANY pattern start near here?" — and only the rare candidate blocks
pay the O(P) verification.  Measured on this backend: >= 3x on
counts/containment for P=32 m=8 over 1 MB (benchmarks/run.py writes the
trajectory to BENCH_multipattern.json).

Exactness never depends on the fingerprint heuristics: candidate overflow
beyond the compaction budget falls back to a dense verification branch via
lax.cond, exactly like core/epsm.py's single-pattern EPSMc.
"""

from __future__ import annotations

import dataclasses
import functools
import weakref
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.epsm import EPSMA_MAX, EPSMB_MAX, EPSMC_BETA, _epsmc_stride
from repro.core.packing import (
    PACK,
    as_u8,
    as_u8_np,
    fingerprint_weights,
    fp_accum_word,
    fp_finalize,
    hash_blocks,
    pack_u32,
    shift_left,
)

# Engine-wide fingerprint width.  Wider than the single-pattern EPSMc table
# (k=11): the union LUT is shared by up to ~hundreds of patterns, and false
# positives cost a whole block verification, so we buy 2^17 * 1 byte of table
# to keep the candidate stream sparse.
ENGINE_KBITS = 17

# --- dictionary scale (bucketed CSR plans, DESIGN.md §14) -------------------
# fp_finalize keeps the TOP kbits of the mixed sum, so the 17-bit engine
# fingerprint is exactly the prefix of any wider one: "bucketing" the union
# LUT into per-prefix sub-tables IS widening kbits by `bbits` — one flat
# probe, sub-LUT semantics.  bbits targets DICT_SLOTS_PER_PATTERN slots per
# pattern so per-slot occupancy (and with it the bounded verify cost and the
# candidate-block density) stays roughly constant as P grows 32 -> 50k.
DICT_BUCKET_MIN_P = 128    # bucket="auto": CSR plans from this group size
DICT_BBITS_MAX = 5         # kbits + bbits <= 22: slot_off tops out at 16 MB
DICT_SLOTS_PER_PATTERN = 64
# Static occupancy cliff: a pattern set whose max slot occupancy exceeds
# this makes even the bounded verify pay slot_max deep per position — route
# straight to the automaton when one was compiled (pattern-set-adversarial
# guard; text-adversarial floods are the lax.cond overflow below).
SLOT_VERIFY_CAP = 64
AUTOMATON_MIN_P = 1024     # automaton="auto": build from this total P
# Expected candidate-BLOCK density (from the static LUT popcount) above
# which the sparse compaction cannot pay: skip it statically and run the
# bounded slot-dense verify (no lax.cond, no wasted union pass).
DENSE_ROUTE_RHO = 0.5
# Block width for compacting per-position EPSMb candidates before the
# fixed-size nonzero: nonzero over n positions is the O(n) floor of the
# sparse path (measured ~40ms/MB on this backend), nonzero over n/32 blocks
# is noise.  32 keeps the verified-position inflation (block granularity vs
# true candidates) small; 128 measured ~1.6x slower end to end.
CAND_BLOCK = 32

# Fingerprint constants live in packing.py next to the mixing primitives;
# the private aliases keep existing importers (approx.relaxed, the Pallas
# kernels) working unchanged.
from repro.core.packing import FP_MULT as _FP_MULT  # noqa: E402
from repro.core.packing import WORD_SALTS as _WORD_SALTS  # noqa: E402

# Plan compilation emits spans/gauges through an optional repro.obs recorder
# (compile-time cost, LUT occupancy, automaton builds, route decisions) —
# same default-disabled pattern as core/stream.py.
import logging  # noqa: E402

from repro.obs.recorder import Recorder, logging_sink  # noqa: E402

_LOG = logging.getLogger("repro.engine")
_DEFAULT_REC = Recorder(enabled=False, fence=False, sinks=(logging_sink(_LOG),))


# ---------------------------------------------------------------------------
# Phase 1: TextIndex — pack & fingerprint the text once
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TextIndex:
    """Pattern-independent view of a (B, n) batch of padded texts."""

    text: jnp.ndarray      # (B, n) uint8
    packed: jnp.ndarray    # (B, n) uint32 — LE-packed 4-gram per position
    block_fp: jnp.ndarray  # (B, n // beta) int32 — aligned beta-block k-bit fps
    lengths: jnp.ndarray   # (B,) int32 — true byte length of each row

    def tree_flatten(self):
        return (self.text, self.packed, self.block_fp, self.lengths), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @property
    def batch(self) -> int:
        return self.text.shape[0]

    @property
    def n(self) -> int:
        return self.text.shape[1]


def build_index(
    texts,
    lengths=None,
    *,
    beta: int = EPSMC_BETA,
    kbits: int = ENGINE_KBITS,
) -> TextIndex:
    """Pack + fingerprint once.  `texts` is (n,) or (B, n) uint8 (or a list
    of byte strings, padded to the longest).  jit-compatible for array input.
    """
    if isinstance(texts, (list, tuple)):
        rows = [np.asarray(jax.device_get(as_u8(t))) for t in texts]
        n = max((len(r) for r in rows), default=0)
        mat = np.zeros((len(rows), n), np.uint8)
        for i, r in enumerate(rows):
            mat[i, : len(r)] = r
        texts = mat
        if lengths is None:
            lengths = np.asarray([len(r) for r in rows], np.int32)
    t = as_u8(texts)
    if t.ndim == 1:
        t = t[None, :]
    if t.ndim != 2:
        raise ValueError("texts must be (n,) or (B, n)")
    B, n = t.shape
    if lengths is None:
        lengths = jnp.full((B,), n, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    packed = pack_u32(t)
    nblk = n // beta
    blocks = t[:, : nblk * beta].reshape(B, nblk, beta)
    block_fp = hash_blocks(blocks, fingerprint_weights(beta), kbits)
    return TextIndex(text=t, packed=packed, block_fp=block_fp, lengths=lengths)


# ---------------------------------------------------------------------------
# Phase 2: PatternPlan — compile a same-length pattern group once
# ---------------------------------------------------------------------------

def _word_offsets(m: int) -> Tuple[int, ...]:
    """Static offsets of the packed u32 words covering bytes [0, m): strided
    4-gram words plus one overlapping final word when m % 4 != 0."""
    offs = list(range(0, m - PACK + 1, PACK))
    if m % PACK and m >= PACK:
        offs.append(m - PACK)
    return tuple(offs)


def _np_pack_words(pats: np.ndarray, offsets) -> np.ndarray:
    """(P, m) uint8 -> (P, nw) uint32 LE-packed anchor words."""
    p32 = pats.astype(np.uint32)
    cols = []
    for o in offsets:
        cols.append(
            p32[:, o]
            | (p32[:, o + 1] << 8)
            | (p32[:, o + 2] << 16)
            | (p32[:, o + 3] << 24)
        )
    return np.stack(cols, axis=1) if cols else np.zeros((pats.shape[0], 0), np.uint32)


def _np_window_fingerprint(words: np.ndarray, kbits: int) -> np.ndarray:
    """Fingerprint of a full window from its packed words (numpy side)."""
    v = np.zeros(words.shape[:-1], np.uint32)
    for i in range(words.shape[-1]):
        v = v + words[..., i] * _WORD_SALTS[i]
    return ((v * _FP_MULT) >> np.uint32(32 - kbits)).astype(np.int32)


def _window_fingerprint(packed: jnp.ndarray, offsets, kbits: int) -> jnp.ndarray:
    """Same fingerprint on the text side: (B, n) packed view -> (B, n) int32
    fingerprint of the m-byte window starting at every position.  O(n) work
    independent of the number of patterns — this is the engine's whole win."""
    v = jnp.zeros(packed.shape, jnp.uint32)
    for i, o in enumerate(offsets):
        v = fp_accum_word(v, shift_left(packed, o), i)
    return fp_finalize(v, kbits)


def _n_strided_words(m: int) -> int:
    """Number of strided (4-aligned, non-overlapping-start) anchor words in
    _word_offsets(m) — the prefix-chain part shared across pattern lengths."""
    return len(range(0, m - PACK + 1, PACK))


class FingerprintBank:
    """Shared incremental window-fingerprint substrate (DESIGN.md §9).

    ``_window_fingerprint`` is a salted sum over the packed words at a
    length's word offsets.  The strided offsets (0, 4, 8, ...) of every
    pattern length form a prefix chain with FIXED salts (salt i belongs to
    offset 4i), so the salted terms can be accumulated ONCE in one traversal
    of ``packed`` and every length group's fingerprint read off as a prefix
    of the running sum — plus, for m % 4 != 0, the group's single
    overlapping tail word.  G length groups thus cost max_nw + G_tail term
    passes over ``packed`` instead of sum_g nw(m_g): one shared fingerprint
    pass for the whole plan set, on the resident path, the streaming path,
    and the approx path alike.

    uint32 addition is commutative and associative mod 2^32, so the derived
    fingerprints are bit-identical to the direct computation.
    """

    def __init__(self, packed: jnp.ndarray):
        self.packed = packed
        # nterms -> accumulated salted sum over strided words [0, nterms)
        self._prefix = {0: jnp.zeros(packed.shape, jnp.uint32)}
        self._fps: dict = {}  # (m, kbits) -> finalized fingerprint map

    def _strided_sum(self, nterms: int) -> jnp.ndarray:
        done = max(t for t in self._prefix if t <= nterms)
        acc = self._prefix[done]
        for i in range(done, nterms):
            acc = fp_accum_word(acc, shift_left(self.packed, PACK * i), i)
            self._prefix[i + 1] = acc
        return self._prefix[nterms]

    def window_fp(self, m: int, kbits: int) -> jnp.ndarray:
        """(B, n) int32 fingerprint of the m-byte window at every position —
        bit-identical to _window_fingerprint(packed, _word_offsets(m), kbits)."""
        key = (m, kbits)
        fp = self._fps.get(key)
        if fp is None:
            ns = _n_strided_words(m)
            v = self._strided_sum(ns)
            if m % PACK and m >= PACK:
                # the one overlapping tail word is group-specific: offset
                # m - 4, salted with the next free salt index (list order)
                v = fp_accum_word(v, shift_left(self.packed, m - PACK), ns)
            fp = fp_finalize(v, kbits)
            self._fps[key] = fp
        return fp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PatternPlan:
    """Compiled matcher state for one equal-length pattern group."""

    m: int                   # static: pattern length
    kbits: int               # static: fingerprint width
    ids: Tuple[int, ...]     # static: original indices of the group's patterns
    distinct: bool           # static: all P window fingerprints unique (EPSMb)
    patterns: jnp.ndarray    # (P, m) uint8
    anchors: jnp.ndarray     # (P, nw) uint32 stacked packed anchor words
    lut_any: jnp.ndarray     # (2^kbits,) bool union fingerprint table
    lut_pid: Optional[jnp.ndarray]   # (2^kbits,) int32 pattern-id payload (EPSMb)
    lut_bits: Optional[jnp.ndarray]  # (2^kbits, ceil(P/32)) uint32 payloads (EPSMc)
    hp: Optional[jnp.ndarray]        # (P, stride) int32 block fps (EPSMc)
    # --- approximate matching (repro.approx, DESIGN.md §8) -----------------
    k: int = 0               # static: mismatch budget the plan was compiled for
    relaxed_lut: Optional[jnp.ndarray] = None  # (2^kbits,) bool <=k-reachable fps
    relaxed_bits: int = 0    # static: set-bit count of relaxed_lut (budgeting)
    # --- dictionary scale: bucketed CSR payloads (DESIGN.md §14) -----------
    # `kbits` above is the WIDENED width (ENGINE_KBITS + bbits) for bucketed
    # plans; the payload bitmask/pid LUTs are replaced by a CSR keyed by the
    # wide fingerprint: slot_off[f] .. slot_off[f+1] index id lists sorted by
    # fingerprint, so a slot's verify gather reads CONSECUTIVE rows of the
    # fp-sorted anchor/pattern tables (grouped gathers), and the per-slot id
    # lists are width-packed (uint16 when P <= 65536).
    bbits: int = 0           # static: widening over ENGINE_KBITS (0 = flat)
    lut_pop: int = 0         # static: union-LUT popcount (budget heuristics)
    slot_max: int = 0        # static: max slot occupancy (verify bound)
    slot_off: Optional[jnp.ndarray] = None       # (2^kbits + 1,) int32 (EPSMb)
    slot_ids: Optional[jnp.ndarray] = None       # (P,) uint16|int32 fp-sorted ids
    anchors_sorted: Optional[jnp.ndarray] = None  # (P, nw) u32 fp-sorted anchors
    c_slot_off: Optional[jnp.ndarray] = None     # (2^kbits + 1,) int32 (EPSMc)
    c_entry_pid: Optional[jnp.ndarray] = None    # (P*stride,) int32 fp-sorted
    c_entry_off: Optional[jnp.ndarray] = None    # (P*stride,) int32 block offset
    c_entry_pat: Optional[jnp.ndarray] = None    # (P*stride, m) u8 grouped rows
    automaton: Optional[Any] = None  # core.automaton.AutomatonPlan fallback

    def tree_flatten(self):
        return (
            (self.patterns, self.anchors, self.lut_any, self.lut_pid,
             self.lut_bits, self.hp, self.relaxed_lut, self.slot_off,
             self.slot_ids, self.anchors_sorted, self.c_slot_off,
             self.c_entry_pid, self.c_entry_off, self.c_entry_pat,
             self.automaton),
            (self.m, self.kbits, self.ids, self.distinct, self.k,
             self.relaxed_bits, self.bbits, self.lut_pop, self.slot_max),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        m, kbits, ids, distinct, k, relaxed_bits, bbits, lut_pop, slot_max = aux
        (patterns, anchors, lut_any, lut_pid, lut_bits, hp, relaxed,
         slot_off, slot_ids, anchors_sorted, c_slot_off, c_entry_pid,
         c_entry_off, c_entry_pat, automaton) = children
        return cls(
            m, kbits, ids, distinct, patterns, anchors, lut_any, lut_pid,
            lut_bits, hp, k=k, relaxed_lut=relaxed, relaxed_bits=relaxed_bits,
            bbits=bbits, lut_pop=lut_pop, slot_max=slot_max,
            slot_off=slot_off, slot_ids=slot_ids,
            anchors_sorted=anchors_sorted, c_slot_off=c_slot_off,
            c_entry_pid=c_entry_pid, c_entry_off=c_entry_off,
            c_entry_pat=c_entry_pat, automaton=automaton,
        )

    @property
    def n_patterns(self) -> int:
        return self.patterns.shape[0]

    @property
    def regime(self) -> str:
        if self.m < EPSMA_MAX:
            return "a"
        if self.m < EPSMB_MAX:
            return "b"
        return "c"


def _dict_bbits(P: int, kbits: int) -> int:
    """Widening that targets DICT_SLOTS_PER_PATTERN slots per pattern."""
    need = int(np.ceil(np.log2(max(2, DICT_SLOTS_PER_PATTERN * P)))) - kbits
    return int(min(DICT_BBITS_MAX, max(0, need)))


def _pow2_ceil(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(0, int(n - 1).bit_length())


def compile_patterns(
    patterns: Sequence,
    *,
    kbits: int = ENGINE_KBITS,
    beta: int = EPSMC_BETA,
    k: int = 0,
    bucket="auto",
    automaton="auto",
    canonical: bool = False,
    recorder: Optional[Recorder] = None,
) -> Tuple[PatternPlan, ...]:
    """Group patterns by length and compile one PatternPlan per group.

    Returned plans are sorted by m; each plan's ``ids`` maps its rows back to
    positions in the input sequence (match_many output is plan-concatenated;
    ``plan_order(plans)`` gives the row -> input-position permutation).

    ``k`` is the mismatch budget the plans are compiled for (repro.approx,
    DESIGN.md §8): plans additionally carry a host-expanded relaxed
    fingerprint LUT covering every window fingerprint reachable under <= k
    byte substitutions, so ``match_many(..., k=k)`` can keep the candidate
    gate before verification.  k=0 plans are bit-identical to before.

    ``bucket`` controls the dictionary-scale CSR compilation (DESIGN.md
    §14): True forces bucketed plans (widened fingerprint + CSR payloads +
    bounded verify), False forces the flat payload LUTs, and "auto" buckets
    any group with >= DICT_BUCKET_MIN_P patterns.  Bucketed and flat plans
    produce bit-identical match/count results at every P — only the route
    (and its worst-case bound) differs.  ``automaton`` gates the packed
    Aho-Corasick fallback (core/automaton.py) attached to bucketed EPSMb
    plans: True forces a build over the WHOLE input dictionary, "auto"
    builds it when the total pattern count reaches AUTOMATON_MIN_P and the
    automaton's size caps hold, False skips it.

    ``canonical`` quantizes every content-dependent static in the plan aux
    data so that jit caching keys on the pattern set's SHAPE signature, not
    its content (DESIGN.md §15).  Concretely: ``lut_pop``, ``slot_max`` and
    ``relaxed_bits`` are rounded up to powers of two (they only feed budget
    heuristics and verify bounds, so rounding is exactness-preserving).
    ``distinct`` stays content-dependent — it is a single bool, so a shape
    signature compiles at most TWO executables, and for the deduplicated
    unions the serving plane builds, fingerprint collisions are rare enough
    (~P^2 / 2^18) that in practice every same-shape union shares one: the
    O(candidates) pid fast path instead of the O(candidates * P) all-
    pattern verify, which is what keeps a coalesced union dispatch near
    flat in P.  Two canonical compiles whose groups agree on (m, P, k,
    bucketing, distinct) hit the same jitted executable — the property the
    serving query plane (repro.serve.query_plane) relies on to coalesce
    arbitrary pattern unions without per-union XLA recompiles.  Default
    False: offline callers keep the content-tuned statics.

    ``recorder`` (repro.obs) captures the compile-time span, per-group LUT
    occupancy/bucket gauges, and automaton build/skip events — the plan-
    build cost BENCH_dictionary reports next to per-dispatch throughput.
    """
    if k < 0:
        raise ValueError("mismatch budget k must be >= 0")
    if bucket not in (True, False, "auto"):
        raise ValueError("bucket must be True, False, or 'auto'")
    if automaton not in (True, False, "auto"):
        raise ValueError("automaton must be True, False, or 'auto'")
    rec = _DEFAULT_REC if recorder is None else recorder
    groups: dict = {}
    arrs: List[np.ndarray] = []
    for i, p in enumerate(patterns):
        arr = as_u8_np(p)
        if arr.ndim != 1 or arr.size == 0:
            raise ValueError("patterns must be non-empty 1-D byte strings")
        groups.setdefault(arr.size, []).append((i, arr))
        arrs.append(arr)

    plans: List[PatternPlan] = []
    with rec.span("plan_compile", groups=len(groups), p_total=len(arrs)):
        for m in sorted(groups):
            ids = tuple(i for i, _ in groups[m])
            pats = np.stack([a for _, a in groups[m]])
            P = pats.shape[0]
            offsets = _word_offsets(m)
            anchors = _np_pack_words(pats, offsets)
            bucketed = m >= EPSMA_MAX and (
                bucket is True or (bucket == "auto" and P >= DICT_BUCKET_MIN_P)
            )
            bbits = _dict_bbits(P, kbits) if bucketed else 0
            kb = kbits + bbits
            lut_any = np.zeros((1 << kb,), np.bool_)
            lut_pid = lut_bits = hp = None
            slot_off = slot_ids = anchors_sorted = None
            c_slot_off = c_entry_pid = c_entry_off = c_entry_pat = None
            slot_max = 0
            slot_cap = P  # total CSR entries; EPSMc registers P * stride
            distinct = False
            if m < EPSMA_MAX:
                pass  # dense byte compares; no fingerprint machinery
            elif m < EPSMB_MAX:
                hw = _np_window_fingerprint(anchors, kb)  # (P,)
                lut_any[hw] = True
                # pattern-id payload: when every pattern owns a unique slot,
                # a candidate position names its ONE claimed pattern and
                # verification compares one gathered anchor instead of all P
                distinct = len(set(hw.tolist())) == P
                if bucketed:
                    # CSR payload: ids sorted by fingerprint; a slot's list
                    # is a CONTIGUOUS run, so the bounded verify's j-th probe
                    # gathers consecutive rows of the fp-sorted anchors
                    order = np.argsort(hw, kind="stable")
                    occ = np.bincount(hw, minlength=1 << kb)
                    slot_off = np.zeros((1 << kb) + 1, np.int32)
                    slot_off[1:] = np.cumsum(occ).astype(np.int32)
                    slot_ids = order.astype(
                        np.uint16 if P <= (1 << 16) else np.int32
                    )
                    anchors_sorted = anchors[order]
                    slot_max = int(occ.max())
                elif distinct:
                    lut_pid = np.zeros((1 << kb,), np.int32)
                    lut_pid[hw] = np.arange(P, dtype=np.int32)
            else:
                # EPSMc: union LUT over the aligned-block fingerprints a true
                # occurrence can present.  Only offsets j < stride are ever
                # probed (the occurrence's unique "dedup" block — see
                # _match_group_c), so only those are registered: fewer
                # entries, fewer false positives.
                stride = _epsmc_stride(m, beta)
                w = np.asarray(
                    jax.device_get(fingerprint_weights(beta))
                ).astype(np.int64)
                offs = np.arange(stride)
                blocks = pats[:, offs[:, None] + np.arange(beta)[None, :]]
                h = (blocks.astype(np.int64) * w[None, None, :]).sum(-1)
                hp = (h & ((1 << kb) - 1)).astype(np.int32)  # (P, stride)
                lut_any[hp.reshape(-1)] = True
                if bucketed:
                    # CSR replaces the (2^k, ceil(P/32)) payload bitmask —
                    # at P=50k that bitmask is ~800 MB; the CSR is
                    # O(P * stride) entries with fp-grouped pattern rows
                    keys = hp.reshape(-1)  # entry e = pid * stride + off
                    order = np.argsort(keys, kind="stable")
                    occ = np.bincount(keys, minlength=1 << kb)
                    c_slot_off = np.zeros((1 << kb) + 1, np.int32)
                    c_slot_off[1:] = np.cumsum(occ).astype(np.int32)
                    c_entry_pid = (order // stride).astype(np.int32)
                    c_entry_off = (order % stride).astype(np.int32)
                    c_entry_pat = pats[c_entry_pid]
                    slot_max = int(occ.max())
                    slot_cap = P * stride
                else:
                    nwords = -(-P // 32)
                    lut_bits = np.zeros((1 << kb, nwords), np.uint32)
                    for p_i in range(P):
                        bit = np.uint32(1 << (p_i % 32))
                        lut_bits[hp[p_i], p_i // 32] |= bit
            lut_pop = int(lut_any.sum())
            relaxed = None
            relaxed_bits = 0
            if k > 0:
                from repro.approx.relaxed import relaxed_window_lut

                relaxed = relaxed_window_lut(pats, kbits=kb, k=k)
                if relaxed is not None:
                    relaxed_bits = int(relaxed.sum())
            if canonical:
                # quantize the budget/bound statics to powers of two: they
                # enter the plan aux data (jit cache key) and trace-time
                # candidate budgets, and rounding UP only loosens exact-by-
                # construction bounds — see the compile_patterns docstring
                lut_pop = min(1 << kb, _pow2_ceil(max(1, lut_pop)))
                if slot_max:
                    # clamp against the plan's TOTAL CSR entry count, not P:
                    # an EPSMc slot can exceed P (patterns sharing a repeated
                    # or common block register the same fingerprint at
                    # several offsets), and rounding slot_max down would make
                    # _c_verify_csr skip live entries and drop matches
                    slot_max = min(slot_cap, _pow2_ceil(slot_max))
                if relaxed_bits:
                    relaxed_bits = min(1 << kb, _pow2_ceil(relaxed_bits))
            rec.event(
                "plan_group", m=m, n_patterns=P, bucketed=int(bucketed),
                bbits=bbits, kbits=kb, lut_pop=lut_pop, slot_max=slot_max,
                occupancy=lut_pop / float(1 << kb),
            )
            rec.gauge(f"plan.lut_occupancy.m{m}", lut_pop / float(1 << kb))
            rec.gauge(f"plan.buckets.m{m}", float(1 << bbits))
            plans.append(
                PatternPlan(
                    m=m,
                    kbits=kb,
                    ids=ids,
                    distinct=distinct,
                    patterns=jnp.asarray(pats),
                    anchors=jnp.asarray(anchors),
                    lut_any=jnp.asarray(lut_any),
                    lut_pid=None if lut_pid is None else jnp.asarray(lut_pid),
                    lut_bits=None if lut_bits is None else jnp.asarray(lut_bits),
                    hp=None if hp is None else jnp.asarray(hp),
                    k=k,
                    relaxed_lut=None if relaxed is None else jnp.asarray(relaxed),
                    relaxed_bits=relaxed_bits,
                    bbits=bbits,
                    lut_pop=lut_pop,
                    slot_max=slot_max,
                    slot_off=None if slot_off is None else jnp.asarray(slot_off),
                    slot_ids=None if slot_ids is None else jnp.asarray(slot_ids),
                    anchors_sorted=(
                        None if anchors_sorted is None
                        else jnp.asarray(anchors_sorted)
                    ),
                    c_slot_off=(
                        None if c_slot_off is None else jnp.asarray(c_slot_off)
                    ),
                    c_entry_pid=(
                        None if c_entry_pid is None else jnp.asarray(c_entry_pid)
                    ),
                    c_entry_off=(
                        None if c_entry_off is None else jnp.asarray(c_entry_off)
                    ),
                    c_entry_pat=(
                        None if c_entry_pat is None else jnp.asarray(c_entry_pat)
                    ),
                )
            )
        # --- packed automaton fallback (core/automaton.py, DESIGN.md §14) --
        # Built over the WHOLE input dictionary in INPUT order, so any plan
        # subset can column-select its counts via plan.ids; attached to every
        # bucketed EPSMb plan (the shared-path fallback consumers).
        want_auto = automaton is True or (
            automaton == "auto"
            and len(arrs) >= AUTOMATON_MIN_P
            and any(p.slot_off is not None for p in plans)
        )
        if want_auto and any(p.slot_off is not None for p in plans):
            from repro.core.automaton import compile_automaton

            with rec.span("automaton_compile", p_total=len(arrs)):
                auto = compile_automaton(arrs)
            if auto is None:
                rec.event("automaton_skipped", p_total=len(arrs))
            else:
                rec.event(
                    "automaton_built", states=auto.n_states,
                    classes=auto.n_classes, entries=auto.n_entries,
                    out_max=auto.out_max,
                )
                plans = [
                    dataclasses.replace(p, automaton=auto)
                    if p.slot_off is not None else p
                    for p in plans
                ]
    return tuple(plans)


def plan_order(plans: Sequence[PatternPlan]) -> np.ndarray:
    """inverse permutation: row i of the concatenated engine output is
    pattern ``order[i]`` of the original input sequence."""
    return np.asarray([i for plan in plans for i in plan.ids], np.int64)


def replicate_plans(
    plans: Sequence[PatternPlan], device
) -> Tuple[PatternPlan, ...]:
    """Copies of compiled plans committed to ``device`` (None = leave as is).

    jit requires colocated inputs, so a scanner dispatching on a non-default
    device needs the plan LUTs/anchors resident there.  The sharded stream
    scanner calls this once per device and reuses the replicas for every
    shard it places on that device — the FingerprintBank each dispatch builds
    then reads the same device-local plan state, with no per-chunk transfer
    (device_put of an already-resident array is a no-op)."""
    if device is None:
        return tuple(plans)
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(x, device), tuple(plans)
    )


_PLAN_CACHE: dict = {}
_PLAN_CACHE_MAX = 64
_PLAN_CACHE_STATS = {"hits": 0, "misses": 0}
# id(array) -> (weakref, canonical-u8 bytes): per-object digest memo so a
# device-resident pattern pays its device_get round-trip ONCE, not on every
# cache probe.  The weakref guards against id() reuse after GC: a recycled
# id maps to a dead (or different) referent and falls through to recompute.
_DIGEST_MEMO: dict = {}
_DIGEST_MEMO_MAX = 256


def _pattern_cache_token(p) -> bytes:
    """Canonical uint8 bytes of one pattern WITHOUT a device round-trip on
    the hot path: host types are serialized directly; device arrays hit a
    per-object digest memo (keyed by id + weakref identity) so only the
    first sighting of an array object pays jax.device_get."""
    if isinstance(p, (bytes, bytearray, memoryview)):
        return bytes(p)
    if isinstance(p, str):
        return p.encode("utf-8", errors="surrogateescape")
    if isinstance(p, np.ndarray):
        a = p if p.dtype == np.uint8 else p.astype(np.uint8)
        return a.tobytes()
    if isinstance(p, (list, tuple)):
        return np.asarray(p).astype(np.uint8).tobytes()
    ent = _DIGEST_MEMO.get(id(p))
    if ent is not None:
        ref, tok = ent
        if ref() is p:
            return tok
    tok = bytes(as_u8_np(p))
    try:
        if len(_DIGEST_MEMO) >= _DIGEST_MEMO_MAX:
            # drop dead entries first; fall back to clearing (rare)
            for i in [i for i, (r, _) in _DIGEST_MEMO.items() if r() is None]:
                del _DIGEST_MEMO[i]
            if len(_DIGEST_MEMO) >= _DIGEST_MEMO_MAX:
                _DIGEST_MEMO.clear()
        _DIGEST_MEMO[id(p)] = (weakref.ref(p), tok)
    except TypeError:
        pass  # not weakref-able: stay correct, just uncached
    return tok


def compile_patterns_cached(
    patterns: Sequence, *, k: int = 0, bucket="auto", automaton="auto",
    canonical: bool = False, recorder: Optional[Recorder] = None,
) -> Tuple[PatternPlan, ...]:
    """compile_patterns with a small host-side LRU memo keyed by pattern
    bytes (and the compile knobs: mismatch budget k, bucket/automaton
    routing, canonical quantization).

    The convenience wrappers (find_multi & co., the batched kernels) receive
    raw pattern stacks per call; without this, every call would pay the
    host-side plan build (2^17 LUT allocation + upload) that PatternSet
    amortizes by construction.  Key construction is transfer-free on cache
    hits: a repeat call with the same (live) device arrays costs dict probes
    only, no jax.device_get (see _pattern_cache_token).  Eviction is
    least-recently-USED (hits refresh recency), so a serving workload's hot
    pattern unions stay resident under tail-churn; hit/miss totals are
    exposed via plan_cache_stats() and, when ``recorder`` is passed, the
    plan_cache.hit / plan_cache.miss counters (DESIGN.md §15)."""
    rec = _DEFAULT_REC if recorder is None else recorder
    key = (k, bucket, automaton, canonical) + tuple(
        _pattern_cache_token(p) for p in patterns
    )
    plans = _PLAN_CACHE.pop(key, None)
    if plans is None:
        _PLAN_CACHE_STATS["misses"] += 1
        rec.count("plan_cache.miss")
        plans = compile_patterns(patterns, k=k, bucket=bucket,
                                 automaton=automaton, canonical=canonical,
                                 recorder=recorder)
        if len(_PLAN_CACHE) >= _PLAN_CACHE_MAX:
            _PLAN_CACHE.pop(next(iter(_PLAN_CACHE)))
    else:
        _PLAN_CACHE_STATS["hits"] += 1
        rec.count("plan_cache.hit")
    _PLAN_CACHE[key] = plans  # (re)insert at the recent end
    return plans


def plan_cache_stats() -> dict:
    """Lifetime hit/miss totals and current size of the plan memo — the
    query plane surfaces these in its stats() snapshot (DESIGN.md §15)."""
    return dict(_PLAN_CACHE_STATS, entries=len(_PLAN_CACHE))


# ---------------------------------------------------------------------------
# Matchers (one per regime).  Each returns mask (B, P, n) or counts (B, P).
# ---------------------------------------------------------------------------

def _valid_starts(
    index: TextIndex, m: int, end_min=None
) -> jnp.ndarray:
    """(B, n) — True where a length-m occurrence may start.  Encodes the
    ragged-padding contract: windows never cross a row's true end, so
    patterns cannot match across document boundaries or inside padding.

    ``end_min`` (traced scalar or None) is the streaming seam bound
    (DESIGN.md §11): when given, a start additionally survives only if its
    occurrence ENDS at or past ``end_min`` (start + m - 1 >= end_min).  This
    is the fused form of the StreamScanner overlap-prefix subtraction — the
    occurrences the two-pass path subtracts via the prefix sub-index are
    exactly the ones this bound excludes — so the seam correction costs one
    compare inside the same gate instead of a second index + count pass.
    None compiles to the exact pre-fusion jaxpr (resident callers pay
    nothing)."""
    n = index.n
    pos = jnp.arange(n, dtype=jnp.int32)[None, :]
    ok = pos <= (index.lengths[:, None] - m)
    if end_min is not None:
        ok = ok & (pos + (m - 1) >= jnp.asarray(end_min, jnp.int32))
    return ok


def _match_group_a(
    index: TextIndex,
    plan: PatternPlan,
    bank: Optional[FingerprintBank] = None,
    end_min=None,
) -> jnp.ndarray:
    """m < 4: dense shifted byte compares (EPSMa, batched over B and P)."""
    del bank  # no fingerprint machinery in this regime
    t = index.text
    acc = _valid_starts(index, plan.m, end_min)[:, None, :]
    for j in range(plan.m):
        acc = acc & (shift_left(t, j)[:, None, :] == plan.patterns[None, :, j, None])
    return acc


def _dense_b(index: TextIndex, plan: PatternPlan, end_min=None) -> jnp.ndarray:
    """Stacked-anchor dense compare: AND over packed word compares.  This is
    the exact EPSMb filter+verify fused — also the overflow fallback."""
    acc = _valid_starts(index, plan.m, end_min)[:, None, :]
    for i, o in enumerate(_word_offsets(plan.m)):
        w = shift_left(index.packed, o)
        acc = acc & (w[:, None, :] == plan.anchors[None, :, i, None])
    return acc


def _expected_union_blocks(
    B: int, n: int, plans: Sequence[PatternPlan], cblock: int = CAND_BLOCK
) -> Tuple[int, float]:
    """(expected candidate blocks, expected block density) from the STATIC
    per-plan LUT popcounts — the satellite fix for the expansion budget.

    The old heuristic ``(B*n*P) >> kbits`` modeled per-POSITION collisions
    against one flat 2^17 table; it ignores (a) slot sharing (P patterns
    occupy lut_pop <= P slots), (b) the widened per-bucket tables of
    dictionary plans (kbits varies per plan), and (c) the block-of-C
    aggregation that actually feeds the nonzero — at high P it both
    over- and under-shoots by orders of magnitude, tripping the dense
    lax.cond fallback on benign text.  The block-level expectation under a
    uniform-fingerprint model is exact: a block of C positions survives when
    ANY of its positions hits ANY plan's occupied slots, so the miss
    probability is prod_g (1 - occ_g)^C with occ_g = lut_pop_g / 2^kbits_g.
    """
    nblk = -(-n // cblock)
    miss = 1.0
    for p in plans:
        occ = min(1.0, p.lut_pop / float(1 << p.kbits))
        miss *= (1.0 - occ) ** cblock
    rho = 1.0 - miss
    return int(B * nblk * rho), rho


def _b_candidates(
    index: TextIndex,
    plan: PatternPlan,
    bank: Optional[FingerprintBank] = None,
    end_min=None,
):
    """Shared-text candidate generation for EPSMb: one O(n) fingerprint +
    union-LUT probe (independent of P), compacted to CAND_BLOCK granularity.
    With a FingerprintBank the fingerprint is a shared-prefix read instead
    of a full per-group recomputation."""
    B, n = index.text.shape
    if bank is None:
        bank = FingerprintBank(index.packed)
    h = bank.window_fp(plan.m, plan.kbits)  # (B, n)
    cand = plan.lut_any[h] & _valid_starts(index, plan.m, end_min)
    C = CAND_BLOCK
    nblk = -(-n // C)
    pad = nblk * C - n
    blk_any = jnp.pad(cand, ((0, 0), (0, pad))).reshape(B, nblk, C).any(-1)
    # budget covers expected fingerprint collisions AND heavy-tailed true-match
    # densities (patterns sampled from the text itself light up ~1/3 of the
    # blocks before the sparse path stops paying); beyond it, dense fallback.
    exp, _ = _expected_union_blocks(B, n, (plan,))
    budget = int(min(B * nblk, max(1024, 4 * exp + 8 * B, (B * nblk) // 3)))
    return blk_any, budget, nblk


def _gather_candidate_rows(
    index: TextIndex, m: int, blk_any, budget, nblk, cblock: int = CAND_BLOCK
):
    """Shared sparse-path prelude: fixed-budget nonzero over candidate
    blocks, gather each block's C+m-1 bytes, re-pack them once.

    ``cblock`` is the candidate-block granularity C (the exact paths use
    CAND_BLOCK; the k-mismatch path uses a smaller block because its relaxed
    LUT is denser — see repro.approx.counting).

    Returns (rows_packed (nb, C+m-1) u32, bvec (nb,), bstart (nb,), live)."""
    B, n = index.text.shape
    C = cblock
    (flat,) = jnp.nonzero(blk_any.reshape(-1), size=budget, fill_value=B * nblk)
    live = flat < B * nblk
    flat = jnp.where(live, flat, 0)
    bvec = flat // nblk
    bstart = (flat % nblk) * C
    t_pad = jnp.pad(index.text, ((0, 0), (0, nblk * C - n + m)))
    rows = t_pad[bvec[:, None], bstart[:, None] + jnp.arange(C + m - 1)]
    return pack_u32(rows), bvec, bstart, live


def _start_gate(index: TextIndex, m: int, starts, bvec, end_min):
    """Per-gathered-start validity: inside the row's true length, plus the
    streaming seam bound when one is given (see _valid_starts)."""
    ok = starts <= (index.lengths[bvec][:, None] - m)
    if end_min is not None:
        ok = ok & (starts + (m - 1) >= jnp.asarray(end_min, jnp.int32))
    return ok


def _b_verify(
    index: TextIndex, plan: PatternPlan, blk_any, budget, nblk, end_min=None
):
    """Gather candidate blocks, re-pack them, verify all positions x patterns.

    Returns (ok (nb, C, P), bvec (nb,), starts (nb, C) with n as the
    out-of-range sentinel)."""
    n = index.text.shape[1]
    m, C = plan.m, CAND_BLOCK
    rows_packed, bvec, bstart, live = _gather_candidate_rows(
        index, m, blk_any, budget, nblk
    )
    ok = None
    for i, o in enumerate(_word_offsets(m)):
        w = rows_packed[:, o : o + C]
        eq = w[:, :, None] == plan.anchors[None, None, :, i]
        ok = eq if ok is None else ok & eq
    starts = bstart[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    in_row = _start_gate(index, m, starts, bvec, end_min)
    ok = ok & (in_row & live[:, None])[:, :, None]
    starts = jnp.where(in_row & live[:, None], starts, n)
    return ok, bvec, starts


def _dense_count(
    index: TextIndex, plan: PatternPlan, dense_fn, end_min=None
) -> jnp.ndarray:
    """Counts via the dense mask (overflow fallback only — the sparse paths
    never materialize (B, P, n))."""
    return dense_fn(index, plan, end_min).sum(-1, dtype=jnp.int32)


def _match_group_b(
    index: TextIndex,
    plan: PatternPlan,
    bank: Optional[FingerprintBank] = None,
    end_min=None,
) -> jnp.ndarray:
    del bank  # dense path — no text-side fingerprint
    # For full (B, P, n) masks the stacked-anchor dense compare is already
    # memory-bound optimal on this backend (the output write dominates), and
    # a candidate scatter of the same size measured ~70x slower.  The union
    # LUT earns its keep on the reduced outputs (_count_group_b), where the
    # (B, P, n) intermediate can be skipped entirely.
    return _dense_b(index, plan, end_min)


def _b_verify_pid(
    index: TextIndex, plan: PatternPlan, blk_any, budget, nblk, end_min=None
):
    """Distinct-fingerprint fast verify: each candidate position names its one
    claimed pattern through the pid payload LUT, so verification gathers and
    compares a SINGLE anchor row per position — O(nb * C) work instead of
    O(nb * C * P).  Returns (ok (nb, C) int32, bvec (nb,), pid (nb, C))."""
    m, C = plan.m, CAND_BLOCK
    rows_packed, bvec, bstart, live = _gather_candidate_rows(
        index, m, blk_any, budget, nblk
    )
    # re-derive the window fingerprint from the gathered rows (cheaper than a
    # second big gather out of the full (B, n) fingerprint map)
    h = _window_fingerprint(rows_packed, _word_offsets(m), plan.kbits)[:, :C]
    candp = plan.lut_any[h]
    pid = plan.lut_pid[h]  # (nb, C) the one pattern that could start here
    sel = plan.anchors[pid]  # (nb, C, nw)
    ok = candp
    for i, o in enumerate(_word_offsets(m)):
        ok = ok & (rows_packed[:, o : o + C] == sel[:, :, i])
    starts = bstart[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    ok = ok & _start_gate(index, m, starts, bvec, end_min) & live[:, None]
    return ok.astype(jnp.int32), bvec, pid


def _automaton_counts(index: TextIndex, auto, end_min=None) -> jnp.ndarray:
    """(B, N_input) exact counts via the packed Aho-Corasick fallback —
    linear in n regardless of candidate density (DESIGN.md §14)."""
    from repro.core.automaton import count_automaton

    return count_automaton(index.text, index.lengths, auto, end_min=end_min)


def _b_count_rows_csr(
    index: TextIndex,
    plan: PatternPlan,
    rows_packed,
    bvec,
    starts,
    live,
    row_bank: FingerprintBank,
    end_min=None,
) -> jnp.ndarray:
    """Bounded CSR verify on gathered candidate rows (bucketed EPSMb).

    Each candidate position probes its wide-fingerprint slot's id list
    (slot_off CSR) and walks at most ``slot_max`` entries; the j-th probe
    gathers CONSECUTIVE rows of the fp-sorted anchor table (the grouped
    gather the CSR sort buys).  O(nb * C * slot_max * nw) — independent of
    P, unlike the flat all-patterns verify's O(nb * C * P * nw)."""
    B = index.text.shape[0]
    C = starts.shape[1]
    P = plan.n_patterns
    h = row_bank.window_fp(plan.m, plan.kbits)[:, :C]
    base = plan.slot_off[h]
    cnt = plan.slot_off[h + 1] - base
    ok_pos = _start_gate(index, plan.m, starts, bvec, end_min) & live[:, None]
    words = [
        rows_packed[:, o : o + C] for o in _word_offsets(plan.m)
    ]
    counts = jnp.zeros((B, P), jnp.int32)
    for j in range(plan.slot_max):
        idx = jnp.minimum(base + j, P - 1)
        sel = plan.anchors_sorted[idx]  # (nb, C, nw) — contiguous per slot
        ok = (j < cnt) & ok_pos
        for i in range(len(words)):
            ok = ok & (words[i] == sel[..., i])
        pid = plan.slot_ids[idx].astype(jnp.int32)
        counts = counts.at[bvec[:, None], pid].add(
            ok.astype(jnp.int32), mode="drop"
        )
    return counts


def _count_b_slot_dense(
    index: TextIndex,
    plan: PatternPlan,
    bank: Optional[FingerprintBank] = None,
    end_min=None,
) -> jnp.ndarray:
    """Slot-dense bounded verify: EVERY position checks its slot's id list.

    This replaces the flat path's O(n * P) dense fallback for bucketed
    plans: cost is O(n * slot_max * nw) with slot_max a COMPILE-TIME
    constant of the pattern set — adversarial text can flood the candidate
    stream but cannot change the per-position bound, so collision floods
    degrade to a linear scan instead of the quadratic verify."""
    B, n = index.text.shape
    P = plan.n_patterns
    if bank is None:
        bank = FingerprintBank(index.packed)
    h = bank.window_fp(plan.m, plan.kbits)  # (B, n)
    base = plan.slot_off[h]
    cnt = plan.slot_off[h + 1] - base
    valid = _valid_starts(index, plan.m, end_min)
    words = [shift_left(index.packed, o) for o in _word_offsets(plan.m)]
    counts = jnp.zeros((B, P), jnp.int32)
    bix = jnp.arange(B, dtype=jnp.int32)[:, None]
    for j in range(plan.slot_max):
        idx = jnp.minimum(base + j, P - 1)
        sel = plan.anchors_sorted[idx]  # (B, n, nw)
        ok = (j < cnt) & valid
        for i in range(len(words)):
            ok = ok & (words[i] == sel[..., i])
        pid = plan.slot_ids[idx].astype(jnp.int32)
        counts = counts.at[bix, pid].add(ok.astype(jnp.int32), mode="drop")
    return counts


# Sparse-vs-dense cliff for the EPSMb count path: the sparse machinery pays
# once the dense (B, P, n) mask would fall out of cache during the reduce
# (measured ~8 MB of mask on this backend); below it, or for tiny pattern
# sets, dense wins.  Shared by the per-group and multi-group count paths.
SPARSE_B_MIN_ELEMS = 8_000_000


def _sparse_b_eligible(index: TextIndex, plan: PatternPlan) -> bool:
    B, n = index.text.shape
    return (
        n >= 4 * CAND_BLOCK
        and plan.n_patterns >= 4
        and B * n * plan.n_patterns >= SPARSE_B_MIN_ELEMS
    )


def _count_group_b(
    index: TextIndex,
    plan: PatternPlan,
    bank: Optional[FingerprintBank] = None,
    end_min=None,
) -> jnp.ndarray:
    B, n = index.text.shape
    P = plan.n_patterns
    if plan.slot_off is not None:
        # bucketed (dictionary-scale) plan: sparse CSR verify with the
        # bounded slot-dense scan as BOTH the static dense-density route and
        # the lax.cond overflow fallback — never the O(n * P) dense compare.
        if bank is None:
            bank = FingerprintBank(index.packed)
        _, rho = _expected_union_blocks(B, n, (plan,))
        if (
            not _sparse_b_eligible(index, plan)
            or rho > DENSE_ROUTE_RHO
            or plan.slot_max > SLOT_VERIFY_CAP
        ):
            return _count_b_slot_dense(index, plan, bank, end_min)
        blk_any, budget, nblk = _b_candidates(index, plan, bank, end_min)

        def sparse_csr(_):
            rows_packed, bvec, bstart, live = _gather_candidate_rows(
                index, plan.m, blk_any, budget, nblk
            )
            starts = (
                bstart[:, None] + jnp.arange(CAND_BLOCK, dtype=jnp.int32)[None, :]
            )
            return _b_count_rows_csr(
                index, plan, rows_packed, bvec, starts, live,
                FingerprintBank(rows_packed), end_min,
            )

        return lax.cond(
            blk_any.sum(dtype=jnp.int32) <= budget,
            sparse_csr,
            lambda _: _count_b_slot_dense(index, plan, bank, end_min),
            None,
        )
    if not _sparse_b_eligible(index, plan):
        return _dense_count(index, plan, _dense_b, end_min)
    blk_any, budget, nblk = _b_candidates(index, plan, bank, end_min)

    def sparse_pid(_):
        ok, bvec, pid = _b_verify_pid(
            index, plan, blk_any, budget, nblk, end_min
        )
        counts = jnp.zeros((B, P), jnp.int32)
        return counts.at[bvec[:, None], pid].add(ok, mode="drop")

    def sparse_all(_):
        ok, bvec, _ = _b_verify(index, plan, blk_any, budget, nblk, end_min)
        # reduce the block axis with a batched matvec: XLA-CPU's plain
        # bool-sum reduce runs at ~5ns/element, the dot lowers to the fast
        # GEMV path (measured 92ms -> 7ms on the budget-sized ok tensor)
        sums = jnp.einsum(
            "bcp,c->bp", ok.astype(jnp.float32),
            jnp.ones((CAND_BLOCK,), jnp.float32),
        )
        counts = jnp.zeros((B, P), jnp.float32)
        return counts.at[bvec].add(sums, mode="drop").astype(jnp.int32)

    sparse = sparse_pid if plan.distinct else sparse_all
    return lax.cond(
        blk_any.sum(dtype=jnp.int32) <= budget,
        sparse,
        lambda _: _dense_count(index, plan, _dense_b, end_min),
        None,
    )


@dataclasses.dataclass(frozen=True)
class _SharedRoute:
    """Static (trace-time) routing decision for one shared EPSMb set."""

    budget: int            # candidate-block budget for the lax.cond gate
    exp_blocks: int        # expected candidate blocks (static model)
    rho: float             # expected candidate-block density
    static_fallback: bool  # skip the sparse machinery entirely
    automaton: Any         # AutomatonPlan to fall back to, or None
    kind: str              # fallback kind: "automaton"|"slot_dense"|"dense"


def _shared_b_route(
    index: TextIndex, plans: Sequence[PatternPlan]
) -> _SharedRoute:
    """One routing decision shared by _count_groups_b_shared and
    route_probe, so the dispatcher and the probe cannot disagree.

    Everything here is host-static (LUT popcounts, slot_max, expected
    density) — the only RUNTIME signal is the measured union block count,
    which the caller compares against ``budget`` inside lax.cond."""
    B, n = index.text.shape
    nblk = -(-n // CAND_BLOCK)
    exp, rho = _expected_union_blocks(B, n, plans)
    # Tighter budget than the per-group path's (B*nblk)//3 heavy-tail slack:
    # every verification op here is paid G-groups-deep on the shared rows,
    # so over-provisioning is G times as expensive, while the bounded
    # fallback below still guarantees exactness on overflow.  2x the
    # expected-collision mass separates textures at dictionary scale, where
    # rho is pinned near DICT_SLOTS_PER_PATTERN/2^bbits-induced ~0.3:
    # average text measures ~exp blocks (inside budget -> sparse gather),
    # while an adversarial fingerprint flood measures ~all blocks, ~3x exp
    # (overflow -> automaton / bounded slot-dense).  A 16x multiplier here
    # would exceed the total block count whenever rho > 1/16 and the
    # measured-density trigger could never fire.  The 8*B + nblk/16 floor
    # keeps benign low-P workloads (tiny exp, bursty real text) sparse.
    budget = int(
        min(B * nblk, max(4096, 2 * exp + 8 * B + (B * nblk) // 16))
    )
    auto = next(
        (p.automaton for p in plans if p.automaton is not None), None
    )
    slot_cap_hit = any(
        p.slot_off is not None and p.slot_max > SLOT_VERIFY_CAP for p in plans
    )
    static_fallback = (slot_cap_hit and auto is not None) or rho > DENSE_ROUTE_RHO
    if auto is not None:
        kind = "automaton"
    elif any(p.slot_off is not None for p in plans):
        kind = "slot_dense"
    else:
        kind = "dense"
    return _SharedRoute(
        budget=budget, exp_blocks=exp, rho=rho,
        static_fallback=static_fallback, automaton=auto, kind=kind,
    )


def _count_groups_b_shared(
    index: TextIndex,
    plans: Sequence[PatternPlan],
    bank: FingerprintBank,
    end_min=None,
) -> jnp.ndarray:
    """Multi-group EPSMb counting with ONE shared candidate pass.

    The per-group sparse path pays an O(n) fingerprint + LUT probe AND an
    O(n) compaction (block reduce, fixed-budget nonzero, candidate-row
    gather + repack) PER GROUP.  Here the G groups share everything the
    algebra allows (DESIGN.md §9): fingerprints come off the
    FingerprintBank's one prefix accumulation; the candidate block masks are
    OR'd into one union; ONE nonzero + ONE row gather (spanning max_m)
    serves every group, which then only verifies its own patterns on the
    shared gathered rows — on a second, rows-sized FingerprintBank for the
    distinct-fingerprint pid fast path.  G length groups thus cost one pass
    over ``packed`` + one compaction instead of G of each.

    Exactness matches the per-group path: the union mask is a superset of
    every group's candidate blocks, verification is the same anchor-word
    compare, and union-budget overflow falls back to the dense count for
    ALL shared groups via lax.cond.
    """
    B, n = index.text.shape
    C = CAND_BLOCK
    nblk = -(-n // C)
    max_m = max(p.m for p in plans)
    route = _shared_b_route(index, plans)

    def fallback(_):
        # Route hierarchy (DESIGN.md §14): packed automaton when any shared
        # plan carries one (it covers the WHOLE input dictionary, so every
        # plan column-selects via ids — linear-time, density-independent);
        # else slot-dense bounded verify for bucketed plans and the classic
        # dense compare for flat ones.
        auto = route.automaton
        if auto is not None:
            ca = _automaton_counts(index, auto, end_min)
            return jnp.concatenate(
                [ca[:, np.asarray(p.ids, np.int64)] for p in plans], axis=1
            )
        outs = []
        for p in plans:
            if p.slot_off is not None:
                outs.append(_count_b_slot_dense(index, p, bank, end_min))
            else:
                outs.append(_dense_count(index, p, _dense_b, end_min))
        return jnp.concatenate(outs, axis=1)

    if route.static_fallback:
        return fallback(None)

    union = None
    for p in plans:
        h = bank.window_fp(p.m, p.kbits)
        cand = p.lut_any[h] & _valid_starts(index, p.m, end_min)
        blk = (
            jnp.pad(cand, ((0, 0), (0, nblk * C - n)))
            .reshape(B, nblk, C)
            .any(-1)
        )
        union = blk if union is None else union | blk

    def sparse(_):
        rows_packed, bvec, bstart, live = _gather_candidate_rows(
            index, max_m, union, route.budget, nblk
        )
        row_bank = FingerprintBank(rows_packed)
        starts = bstart[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        outs = []
        for p in plans:
            in_row = _start_gate(index, p.m, starts, bvec, end_min)
            ok_pos = in_row & live[:, None]
            if p.slot_off is not None:
                # bounded CSR verify on the shared rows (dictionary plans)
                outs.append(
                    _b_count_rows_csr(
                        index, p, rows_packed, bvec, starts, live,
                        row_bank, end_min,
                    )
                )
            elif p.distinct:
                # pid fast path on the shared rows: O(nb * C) per group
                h = row_bank.window_fp(p.m, p.kbits)[:, :C]
                pid = p.lut_pid[h]
                sel = p.anchors[pid]  # (nb, C, nw)
                ok = p.lut_any[h]
                for i, o in enumerate(_word_offsets(p.m)):
                    ok = ok & (rows_packed[:, o : o + C] == sel[:, :, i])
                ok = (ok & ok_pos).astype(jnp.int32)
                counts = jnp.zeros((B, p.n_patterns), jnp.int32)
                outs.append(
                    counts.at[bvec[:, None], pid].add(ok, mode="drop")
                )
            else:
                ok = None
                for i, o in enumerate(_word_offsets(p.m)):
                    eq = (
                        rows_packed[:, o : o + C, None]
                        == p.anchors[None, None, :, i]
                    )
                    ok = eq if ok is None else ok & eq
                ok = ok & ok_pos[:, :, None]
                sums = jnp.einsum(
                    "bcp,c->bp", ok.astype(jnp.float32),
                    jnp.ones((C,), jnp.float32),
                )
                counts = jnp.zeros((B, p.n_patterns), jnp.float32)
                outs.append(
                    counts.at[bvec].add(sums, mode="drop").astype(jnp.int32)
                )
        return jnp.concatenate(outs, axis=1)

    return lax.cond(
        union.sum(dtype=jnp.int32) <= route.budget, sparse, fallback, None
    )


# Fallback for EPSMc overflow: dense shifted byte compares — O(m) passes but
# memory-bounded at (B, P, n).  Same computation as the EPSMa matcher, which
# is exact for every m.  (A wrapper, not an alias: _dense_count passes
# end_min as the 3rd positional, which must not bind to `bank`.)
def _dense_c(index: TextIndex, plan: PatternPlan, end_min=None) -> jnp.ndarray:
    return _match_group_a(index, plan, None, end_min)


def _c_candidates(index: TextIndex, plan: PatternPlan):
    """Probe the union LUT at the strided inspected blocks (paper Fig. 1
    bottom, many patterns at once).  Every occurrence has exactly ONE
    inspected block with offset j < stride inside its window (the dedup
    block), so candidates are found — and counted — exactly once.

    Bucketed plans fingerprint at the WIDENED kbits, which the TextIndex's
    shared block_fp (built at ENGINE_KBITS) cannot serve — those recompute
    the strided blocks' wide fingerprints from the text (O(B * G * beta)
    extra work, bought back many times over by the bounded CSR verify)."""
    beta = EPSMC_BETA
    stride = _epsmc_stride(plan.m, beta)
    step = stride // beta
    if plan.bbits > 0:
        B_, n_ = index.text.shape
        nb = n_ // beta
        blocks = index.text[:, : nb * beta].reshape(B_, nb, beta)
        ht = hash_blocks(blocks, fingerprint_weights(beta), plan.kbits)[
            :, ::step
        ]
    else:
        ht = index.block_fp[:, ::step]  # (B, G) — strided view, no gather
    cand = plan.lut_any[ht]
    B, G = cand.shape
    noff_used = min(stride, plan.m - beta + 1)
    # block-level expectation from the static popcount (see
    # _expected_union_blocks): each inspected block is ONE probe
    exp = int(B * G * min(1.0, plan.lut_pop / float(1 << plan.kbits)))
    budget = int(min(max(B * G, 1), max(64, 4 * exp + 8 * B)))
    return ht, cand, stride, noff_used, budget


def _c_verify(index, plan, ht, cand, stride, noff_used, budget, end_min=None):
    """Verify candidate blocks against all P patterns at the <= stride
    offsets, gated by the LUT's pattern-id payload bitmask."""
    B, n = index.text.shape
    m = plan.m
    G = cand.shape[1]
    (flat,) = jnp.nonzero(cand.reshape(-1), size=budget, fill_value=B * G)
    live = flat < B * G
    flat = jnp.where(live, flat, 0)
    bvec = flat // G
    bsel = (flat % G) * stride  # inspected block start
    front = noff_used - 1
    span = front + m
    t_pad = jnp.pad(index.text, ((0, 0), (front, span)))
    rows = t_pad[bvec[:, None], bsel[:, None] + jnp.arange(span)]  # (nb, span)
    # pattern-id payload: which patterns registered this fingerprint?
    P = plan.n_patterns
    bits = plan.lut_bits[ht.reshape(-1)[jnp.where(live, flat, 0)]]  # (nb, W)
    word = jnp.arange(P) // 32
    shift = jnp.arange(P, dtype=jnp.uint32) % 32
    pgate = ((bits[:, word] >> shift[None, :]) & 1).astype(jnp.bool_)  # (nb, P)
    oks, sts = [], []
    for j in range(noff_used):
        win = rows[:, front - j : front - j + m]  # window starting at bsel - j
        st = bsel - j
        in_row = (st >= 0) & (st <= index.lengths[bvec] - m)
        if end_min is not None:
            in_row = in_row & (st + (m - 1) >= jnp.asarray(end_min, jnp.int32))
        ok = (
            pgate
            & (live & in_row)[:, None]
            & jnp.all(win[:, None, :] == plan.patterns[None, :, :], axis=-1)
        )
        oks.append(ok)
        sts.append(jnp.where(live & in_row, st, n))
    ok_all = jnp.concatenate(oks)        # (noff_used * nb, P)
    st_all = jnp.concatenate(sts)        # (noff_used * nb,)
    b_all = jnp.concatenate([bvec] * noff_used)
    return ok_all, b_all, st_all


def _c_verify_csr(
    index, plan, ht, cand, stride, noff_used, budget, end_min=None
):
    """Bounded CSR verify for bucketed EPSMc plans (DESIGN.md §14).

    The flat payload bitmask tests every candidate block against all P
    patterns at all < stride offsets — O(nb * P * stride) compares and an
    O(2^k * P / 32) bitmask that reaches ~800 MB at P = 50k.  Here a
    candidate block's wide fingerprint names a CSR slot whose entries are
    exactly the (pattern, offset) pairs that registered it, so the verify
    is O(nb * slot_max * m) with slot_max a COMPILE-TIME constant:
    adversarial text can flood candidates but cannot change the per-block
    bound.  Each true occurrence is tested at exactly one (block, entry)
    pair — its unique dedup block and its registered offset — so counts
    stay bit-identical to the flat path.

    Returns per-entry (ok, pid, b, start) vectors of length
    slot_max * nb for scatter-add/scatter-max joins.
    """
    B, n = index.text.shape
    m = plan.m
    G = cand.shape[1]
    (flat,) = jnp.nonzero(cand.reshape(-1), size=budget, fill_value=B * G)
    live = flat < B * G
    flat = jnp.where(live, flat, 0)
    bvec = flat // G
    bsel = (flat % G) * stride  # inspected block start
    front = noff_used - 1
    span = front + m
    t_pad = jnp.pad(index.text, ((0, 0), (front, span)))
    rows = t_pad[bvec[:, None], bsel[:, None] + jnp.arange(span)]  # (nb, span)
    h = ht.reshape(-1)[flat]
    base = plan.c_slot_off[h]
    cnt = plan.c_slot_off[h + 1] - base
    E = plan.c_entry_pid.shape[0]
    oks, pids, sts = [], [], []
    for j in range(plan.slot_max):
        idx = jnp.minimum(base + j, E - 1)
        e_live = live & (j < cnt)
        pid = plan.c_entry_pid[idx]
        off = plan.c_entry_off[idx]
        pat = plan.c_entry_pat[idx]  # (nb, m)
        win = jnp.take_along_axis(
            rows, (front - off)[:, None] + jnp.arange(m)[None, :], axis=1
        )
        st = bsel - off
        in_row = (st >= 0) & (st <= index.lengths[bvec] - m)
        if end_min is not None:
            in_row = in_row & (
                st + (m - 1) >= jnp.asarray(end_min, jnp.int32)
            )
        ok = e_live & in_row & jnp.all(win == pat, axis=-1)
        oks.append(ok)
        pids.append(pid)
        sts.append(jnp.where(ok, st, n))
    ok_all = jnp.concatenate(oks)        # (slot_max * nb,)
    pid_all = jnp.concatenate(pids)
    st_all = jnp.concatenate(sts)
    b_all = jnp.concatenate([bvec] * plan.slot_max)
    return ok_all, pid_all, b_all, st_all


def _match_group_c(
    index: TextIndex,
    plan: PatternPlan,
    bank: Optional[FingerprintBank] = None,
    end_min=None,
) -> jnp.ndarray:
    del bank  # keyed by aligned block fingerprints, not window fingerprints
    B, n = index.text.shape
    P = plan.n_patterns
    if index.block_fp.shape[1] == 0:
        return _dense_c(index, plan, end_min)
    ht, cand, stride, noff_used, budget = _c_candidates(index, plan)

    if plan.c_slot_off is not None:
        # bucketed: the bounded CSR verify IS the overflow path too — run
        # on every inspected block (budget B * G) instead of densifying,
        # keeping the adversarial bound O(B * G * slot_max * m)
        def sparse_csr(_):
            ok, pid, b_all, st_all = _c_verify_csr(
                index, plan, ht, cand, stride, noff_used, budget, end_min
            )
            out = jnp.zeros((B, P, n + 1), jnp.bool_)
            out = out.at[b_all, pid, st_all].max(ok, mode="drop")
            return out[:, :, :n]

        def full_csr(_):
            ok, pid, b_all, st_all = _c_verify_csr(
                index, plan, ht, jnp.ones_like(cand), stride, noff_used,
                cand.size, end_min,
            )
            out = jnp.zeros((B, P, n + 1), jnp.bool_)
            out = out.at[b_all, pid, st_all].max(ok, mode="drop")
            return out[:, :, :n]

        return lax.cond(
            cand.sum(dtype=jnp.int32) <= budget, sparse_csr, full_csr, None
        )

    def sparse(_):
        ok, b_all, st_all = _c_verify(
            index, plan, ht, cand, stride, noff_used, budget, end_min
        )
        out = jnp.zeros((B, P, n + 1), jnp.bool_)
        out = out.at[
            b_all[:, None, None], jnp.arange(P)[None, None, :], st_all[:, None, None]
        ].max(ok[:, None, :], mode="drop")
        return out[:, :, :n]

    return lax.cond(
        cand.sum(dtype=jnp.int32) <= budget,
        sparse,
        lambda _: _dense_c(index, plan, end_min),
        None,
    )


def _count_group_c(
    index: TextIndex,
    plan: PatternPlan,
    bank: Optional[FingerprintBank] = None,
    end_min=None,
) -> jnp.ndarray:
    del bank  # keyed by aligned block fingerprints, not window fingerprints
    B = index.batch
    if index.block_fp.shape[1] == 0:
        return _dense_c(index, plan, end_min).sum(-1, dtype=jnp.int32)
    ht, cand, stride, noff_used, budget = _c_candidates(index, plan)

    if plan.c_slot_off is not None:
        def sparse_csr(_):
            ok, pid, b_all, _ = _c_verify_csr(
                index, plan, ht, cand, stride, noff_used, budget, end_min
            )
            counts = jnp.zeros((B, plan.n_patterns), jnp.int32)
            return counts.at[b_all, pid].add(
                ok.astype(jnp.int32), mode="drop"
            )

        def full_csr(_):
            ok, pid, b_all, _ = _c_verify_csr(
                index, plan, ht, jnp.ones_like(cand), stride, noff_used,
                cand.size, end_min,
            )
            counts = jnp.zeros((B, plan.n_patterns), jnp.int32)
            return counts.at[b_all, pid].add(
                ok.astype(jnp.int32), mode="drop"
            )

        return lax.cond(
            cand.sum(dtype=jnp.int32) <= budget, sparse_csr, full_csr, None
        )

    def sparse(_):
        ok, b_all, _ = _c_verify(
            index, plan, ht, cand, stride, noff_used, budget, end_min
        )
        counts = jnp.zeros((B, plan.n_patterns), jnp.int32)
        return counts.at[b_all].add(ok.astype(jnp.int32), mode="drop")

    return lax.cond(
        cand.sum(dtype=jnp.int32) <= budget,
        sparse,
        lambda _: _dense_count(index, plan, _dense_c, end_min),
        None,
    )


_MATCH = {"a": _match_group_a, "b": _match_group_b, "c": _match_group_c}
_COUNT = {
    "a": lambda idx, plan, bank=None, end_min=None: _match_group_a(
        idx, plan, None, end_min
    ).sum(-1, dtype=jnp.int32),
    "b": _count_group_b,
    "c": _count_group_c,
}


# ---------------------------------------------------------------------------
# Public joins: one dispatch for P patterns x B texts
# ---------------------------------------------------------------------------

def _effective_k(plan: PatternPlan, k: Optional[int]) -> int:
    """Per-plan mismatch budget: an explicit k overrides; None means "what
    the plan was compiled for" (0 for exact plans), so fuzzy-compiled plans
    flow through existing call sites (serving, blocklist) unchanged."""
    return plan.k if k is None else int(k)


def match_many(
    index: TextIndex,
    plans: Sequence[PatternPlan],
    *,
    k: Optional[int] = None,
    end_min: Optional[int] = None,
) -> jnp.ndarray:
    """bool[B, P_total, n] match-start masks, rows in plan-concatenated order
    (use :func:`plan_order` to map back to the original pattern order) — the
    engine's one-dispatch join of a TextIndex with compiled plans
    (DESIGN.md §7).

    ``k`` is the mismatch budget (repro.approx): mask[b, p, i] is True iff
    the m-byte window at i differs from pattern p in at most k bytes.  k=0
    (or exact-compiled plans with k=None) runs the exact matchers unchanged —
    bit-identical to the pre-approx engine.

    ``end_min`` keeps only occurrences ENDING at position >= end_min (the
    streaming seam gate — DESIGN.md §11): equivalent to subtracting a
    prefix-window scan, fused into the candidate gates of every regime."""
    if not plans:
        return jnp.zeros((index.batch, 0, index.n), jnp.bool_)
    bank = FingerprintBank(index.packed)
    outs = []
    for p in plans:
        kk = _effective_k(p, k)
        if kk == 0:
            outs.append(_MATCH[p.regime](index, p, bank, end_min))
        else:
            from repro.approx import counting

            outs.append(counting.match_group_approx(index, p, kk, end_min))
    return jnp.concatenate(outs, axis=1)


def count_many(
    index: TextIndex,
    plans: Sequence[PatternPlan],
    *,
    k: Optional[int] = None,
    end_min: Optional[int] = None,
    shared: bool = True,
) -> jnp.ndarray:
    """int32[B, P_total] occurrence counts — the reduced hot path: the
    exact and relaxed-gated paths never materialize the (B, P, n) mask.
    ``k`` as in :func:`match_many`; note the k > 0 DENSE path (small P,
    saturated or absent relaxed LUT, or candidate overflow) does build the
    (B, P, n) mismatch mask before reducing.

    All groups draw their window fingerprints from ONE FingerprintBank
    prefix accumulation, and every sparse-eligible EPSMb group additionally
    shares a single candidate compaction (_count_groups_b_shared) — G length
    groups cost one pass over the packed view, not G (DESIGN.md §9).  The
    shared pass runs even for a single eligible group so mixed plan sets
    never silently fall back to the slower per-group compaction.

    ``end_min`` as in :func:`match_many` (streaming seam gate).

    ``shared=False`` disables the shared-compaction routing and counts every
    group through its own per-group matcher (_COUNT dispatch) — the
    pre-fusion per-group reference path benchmarks and oracle tests pin
    against."""
    if not plans:
        return jnp.zeros((index.batch, 0), jnp.int32)
    bank = FingerprintBank(index.packed)
    outs: List[Any] = [None] * len(plans)
    # Exact EPSMb groups on the sparse path: count them together through
    # the shared candidate pass (one fingerprint traversal + one compaction
    # for all of them — see _count_groups_b_shared).  A single eligible
    # group still routes here: the shared pass degenerates gracefully and
    # keeps the dispatch count flat across mixed plan sets.
    shared_idx = [
        i
        for i, p in enumerate(plans)
        if shared
        and _effective_k(p, k) == 0
        and p.regime == "b"
        and _sparse_b_eligible(index, p)
    ]
    if len(shared_idx) >= 1:
        joint = _count_groups_b_shared(
            index, [plans[i] for i in shared_idx], bank, end_min
        )
        col = 0
        for i in shared_idx:
            P = plans[i].n_patterns
            outs[i] = joint[:, col : col + P]
            col += P
    for i, p in enumerate(plans):
        if outs[i] is not None:
            continue
        kk = _effective_k(p, k)
        if kk == 0:
            outs[i] = _COUNT[p.regime](index, p, bank, end_min)
        else:
            from repro.approx import counting

            outs[i] = counting.count_group_approx(index, p, kk, bank, end_min)
    return jnp.concatenate(outs, axis=1)


def route_probe(
    index: TextIndex,
    plans: Sequence[PatternPlan],
    *,
    k: Optional[int] = None,
    end_min: Optional[int] = None,
    shared: bool = True,
    recorder: Optional[Recorder] = None,
) -> dict:
    """Report WHICH route count_many would take for this (index, plans)
    pair without running the verification — the observability half of the
    dictionary-scale dispatcher (DESIGN.md §14, BENCH_dictionary's "route"
    column).

    Uses the same _shared_b_route decision and the same union-block
    measurement as _count_groups_b_shared, so the probe and the dispatcher
    cannot disagree.  Emits a ``fallback_route`` event on ``recorder``
    (repro.obs) with the chosen route, measured candidate blocks, budget,
    and density.  Host-synchronizing (materializes the union popcount) —
    a diagnostic, not a hot-path call.
    """
    rec = _DEFAULT_REC if recorder is None else recorder
    B, n = index.text.shape
    C = CAND_BLOCK
    nblk = -(-n // C)
    shared_plans = [
        p
        for p in plans
        if shared
        and _effective_k(p, k) == 0
        and p.regime == "b"
        and _sparse_b_eligible(index, p)
    ]
    if not shared_plans:
        info = {
            "route": "per_group",
            "kind": "none",
            "blocks": 0,
            "budget": 0,
            "total_blocks": B * nblk,
            "density": 0.0,
            "rho": 0.0,
            "static": True,
        }
        rec.event("fallback_route", **info)
        return info
    route = _shared_b_route(index, shared_plans)
    blocks = 0
    if not route.static_fallback:
        bank = FingerprintBank(index.packed)
        union = None
        for p in shared_plans:
            h = bank.window_fp(p.m, p.kbits)
            cand = p.lut_any[h] & _valid_starts(index, p.m, end_min)
            blk = (
                jnp.pad(cand, ((0, 0), (0, nblk * C - n)))
                .reshape(B, nblk, C)
                .any(-1)
            )
            union = blk if union is None else union | blk
        blocks = int(union.sum(dtype=jnp.int32))
    overflow = route.static_fallback or blocks > route.budget
    info = {
        "route": route.kind if overflow else "sparse",
        "kind": route.kind,
        "blocks": blocks,
        "budget": route.budget,
        "exp_blocks": route.exp_blocks,
        "total_blocks": B * nblk,
        "density": blocks / float(max(1, B * nblk)),
        "rho": route.rho,
        "static": bool(route.static_fallback),
    }
    rec.event("fallback_route", **info)
    return info


def any_many(
    index: TextIndex, plans: Sequence[PatternPlan], *, k: Optional[int] = None
) -> jnp.ndarray:
    """bool[B, P_total] — does pattern p occur anywhere in text b?"""
    return count_many(index, plans, k=k) > 0


def any_hit(
    index: TextIndex, plans: Sequence[PatternPlan], *, k: Optional[int] = None
) -> jnp.ndarray:
    """bool[B] — does ANY pattern occur in text b?  (blocklist predicate)"""
    return any_many(index, plans, k=k).any(axis=-1)


@functools.partial(jax.jit, static_argnames=("k",))
def match_many_jit(
    index: TextIndex, plans: Tuple[PatternPlan, ...], *, k: Optional[int] = None
) -> jnp.ndarray:
    """Module-level jitted :func:`match_many`: callers that share this entry
    point share one XLA executable cache keyed on (index shapes, plan aux
    statics, k) — canonical plans make that key content-independent
    (DESIGN.md §15)."""
    return match_many(index, plans, k=k)


@functools.partial(jax.jit, static_argnames=("k",))
def count_many_jit(
    index: TextIndex, plans: Tuple[PatternPlan, ...], *, k: Optional[int] = None
) -> jnp.ndarray:
    """Module-level jitted :func:`count_many` — see :func:`match_many_jit`
    for the executable-cache sharing contract."""
    return count_many(index, plans, k=k)


@jax.jit
def _blocked_jit(texts: jnp.ndarray, lengths: jnp.ndarray, plans) -> jnp.ndarray:
    """One fused dispatch: build the TextIndex AND run the blocklist check."""
    return any_hit(build_index(texts, lengths), plans)


def blocked(texts, lengths, plans) -> jnp.ndarray:
    """bool[B] blocklist predicate over a padded (B, L) batch of documents."""
    return _blocked_jit(jnp.asarray(texts), jnp.asarray(lengths), tuple(plans))
