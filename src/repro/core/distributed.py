"""Distributed packed string matching: the paper's scan as a collective program.

The corpus is sharded along one (or a flattened tuple of) mesh axes; each
device runs the packed scan on its shard; the (m-1)-byte halo needed for
occurrences crossing shard boundaries moves via lax.ppermute (one neighbor
exchange — the cheapest collective there is); counts are psum'd.

This mirrors, at pod scale, exactly what wsblend did at register scale in the
paper: stitching two adjacent blocks so no alignment is lost.
"""

from __future__ import annotations

import functools
from typing import Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import epsm
from repro.core.packing import as_u8
from repro.dist.compat import axis_size as _axis_size_of, shard_map

AxisNames = Union[str, tuple]


def _axis_size(axis_names: AxisNames) -> jnp.ndarray:
    if isinstance(axis_names, str):
        return _axis_size_of(axis_names)
    size = 1
    for a in axis_names:
        size = size * _axis_size_of(a)
    return size


def _flat_index(axis_names: AxisNames) -> jnp.ndarray:
    if isinstance(axis_names, str):
        return lax.axis_index(axis_names)
    idx = jnp.int32(0)
    for a in axis_names:
        idx = idx * _axis_size_of(a) + lax.axis_index(a)
    return idx


def _next_rank_halo(shard: jnp.ndarray, halo: int, axis_names: AxisNames) -> jnp.ndarray:
    """Exact next-flat-rank halo exchange (handles multi-axis sharding)."""
    if isinstance(axis_names, str):
        k = _axis_size_of(axis_names)
        head = lax.ppermute(
            shard[:halo], axis_names, perm=[(i, (i - 1) % k) for i in range(k)]
        )
        return jnp.concatenate([shard, head])
    # flatten (a, b, ...) into one logical ring: permute fastest axis cyclically,
    # and at its boundary carry into the slower axes via a second permute.
    names = tuple(axis_names)
    head = shard[:halo]
    # Build the flattened ring permutation as a composition of per-axis
    # ppermutes is fragile; instead use ppermute over each axis with the
    # boundary-carry trick: receive from (flat+1), i.e. send to (flat-1).
    fast = names[-1]
    kf = _axis_size_of(fast)
    # everyone sends head to previous rank on fast axis
    recv_fast = lax.ppermute(head, fast, perm=[(i, (i - 1) % kf) for i in range(kf)])
    if len(names) == 1:
        return jnp.concatenate([shard, recv_fast])
    # ranks whose fast index == kf-1 must instead receive from the next slow
    # rank's fast index 0. recv_fast at those ranks currently holds the head of
    # fast index 0 of the SAME slow rank; fix by shifting that value along the
    # slow axes for boundary ranks.
    slow = names[:-1]
    carried = recv_fast
    for a in reversed(slow):
        k = _axis_size_of(a)
        carried = lax.ppermute(carried, a, perm=[(i, (i - 1) % k) for i in range(k)])
    at_boundary = lax.axis_index(fast) == kf - 1
    head_next = jnp.where(at_boundary, carried, recv_fast)
    return jnp.concatenate([shard, head_next])


def make_distributed_find(mesh, axis_names: AxisNames = "data", *, algo: str = "auto"):
    """Build a shard_map'ed (text, pattern) -> mask function over `mesh`."""
    spec = P(axis_names)

    def local(text_shard: jnp.ndarray, pattern: jnp.ndarray) -> jnp.ndarray:
        m = pattern.shape[0]
        ln = text_shard.shape[0]
        ext = _next_rank_halo(text_shard, m - 1, axis_names) if m > 1 else text_shard
        mask = epsm.find(ext, pattern, algo=algo)[:ln]
        # the last shard's halo wraps to shard 0: kill starts that would cross
        # the global end of the text.
        k = _axis_size(axis_names)
        is_last = _flat_index(axis_names) == k - 1
        tail_ok = jnp.arange(ln) <= (ln - m)
        return jnp.where(is_last, mask & tail_ok, mask)

    fn = shard_map(
        local, mesh=mesh, in_specs=(spec, P()), out_specs=spec, check_vma=False
    )
    return fn


def make_distributed_count(mesh, axis_names: AxisNames = "data", *, algo: str = "auto"):
    find_fn_local_spec = P(axis_names)

    def local(text_shard: jnp.ndarray, pattern: jnp.ndarray) -> jnp.ndarray:
        m = pattern.shape[0]
        ln = text_shard.shape[0]
        ext = _next_rank_halo(text_shard, m - 1, axis_names) if m > 1 else text_shard
        mask = epsm.find(ext, pattern, algo=algo)[:ln]
        k = _axis_size(axis_names)
        is_last = _flat_index(axis_names) == k - 1
        tail_ok = jnp.arange(ln) <= (ln - m)
        mask = jnp.where(is_last, mask & tail_ok, mask)
        local_count = mask.sum(dtype=jnp.int32)
        return lax.psum(local_count, axis_names)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(find_fn_local_spec, P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn
