"""Streaming scan engine: bounded-memory single-pass matching over unbounded
texts (DESIGN.md §9).

The resident engine (core/engine.py) wants the whole corpus on device —
``build_index`` materializes text + packed + block_fp for the full (B, n)
batch, ~9 bytes of device memory per byte of input.  That blocks the
ROADMAP's grep/log-scan/pipeline-filter workloads the moment a corpus
outgrows the device.  This module answers the same count/any/positions
queries EXACTLY over arbitrarily long inputs in O(chunk) device memory:

  * :class:`StreamScanner` re-chunks any byte source (bytes, arrays, files,
    iterables of chunks) into fixed-capacity windows, carries an
    ``overlap`` tail of ``max_m - 1`` bytes (rounded up to the EPSMc beta
    block so every window starts on a GLOBAL beta boundary — the
    block-phase carry) across windows, and issues exactly ONE jitted
    dispatch per chunk;

  * seam exactness is by END-position attribution: a window counts only the
    occurrences whose last byte falls in its newly-streamed region.  Any
    occurrence ending there started at most max_m - 1 bytes earlier, i.e.
    inside the carried overlap, so its full window is visible; occurrences
    ending inside the overlap were already counted by the previous window
    and are subtracted via a tiny (overlap-sized) prefix sub-index inside
    the same dispatch.  Each occurrence is therefore counted exactly once —
    no misses and no double counts at seams (invariants: DESIGN.md §9);

  * the host/device loop is double-buffered: chunk i+1 is ``device_put``
    while chunk i's dispatch computes (JAX dispatch is asynchronous), and
    the device-side count accumulator is a donated buffer on accelerator
    backends, so streaming adds no per-chunk sync and no growing state.

Approximate plans stream too: a <= k-mismatch occurrence spans the same m
bytes as an exact one, so the overlap/attribution argument is untouched and
``count_many(..., k=k)`` (relaxed gate and all) simply runs per chunk.

Two extensions ride on the same seam rule (DESIGN.md §10):

  * a scanner can start MID-stream: ``count_many/masks(..., prefix=, start=)``
    inject a carried overlap prefix and a global byte offset, so disjoint
    ranges of one logical stream can be scanned by different scanners (or
    hosts — core/shard_stream.py) and merged exactly, the shard boundary
    being just a second-level window seam;

  * sources may be gzip/zstd-compressed: wrap them in :class:`Compressed`
    and frames decompress incrementally into the same O(chunk) window
    (cold-storage corpora never materialize, and decompression overlaps
    device compute exactly like the host->device copy does).
"""

from __future__ import annotations

import functools
import logging
import time
from collections import deque
from typing import Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.engine import PatternPlan
from repro.core.epsm import EPSMC_BETA
from repro.obs.recorder import Recorder, logging_sink

_LOG = logging.getLogger("repro.stream")

# The module's default flight recorder: disabled (no spans, no fencing, no
# buffers — the <2% bench_obs budget) but with the module logger as an event
# sink, so the pre-recorder log lines (auto-chunk probe, kernel fallback,
# stragglers) keep appearing when no recorder is attached (DESIGN.md §13).
_DEFAULT_REC = Recorder(enabled=False, fence=False, sinks=(logging_sink(_LOG),))

# Floor device window capacity (bytes) for adaptive sizing, and the value a
# backend with no memory stats and negligible dispatch overhead lands on.
# ~4 MiB keeps per-chunk dispatch overhead amortized while the working set
# (window + packed + block_fp + fingerprint temporaries, ~9.5 bytes/byte)
# stays far below any device's memory.
DEFAULT_CHUNK_BYTES = 1 << 22
# Adaptive sizing bounds: never below 1 MiB (seam overhead dominates), never
# above 128 MiB (diminishing amortization, fast fallback compile times).
MIN_CHUNK_BYTES = 1 << 20
MAX_CHUNK_BYTES = 1 << 27
# read() granularity for file-like sources
_READ_BYTES = 1 << 20

_DISPATCH_OVERHEAD_S: Optional[float] = None


def _dispatch_overhead_s() -> float:
    """One-time measured per-dispatch overhead of this backend (seconds):
    the amortized cost of pushing one trivial jitted computation through the
    dispatch path.  Cached for the process — the probe is a few dozen tiny
    dispatches, microseconds each."""
    global _DISPATCH_OVERHEAD_S
    if _DISPATCH_OVERHEAD_S is None:
        f = jax.jit(lambda x: x + 1)
        x = jnp.zeros((8,), jnp.int32)
        f(x).block_until_ready()  # compile outside the timed region
        reps = 32
        t0 = time.perf_counter()
        for _ in range(reps):
            x = f(x)
        x.block_until_ready()
        _DISPATCH_OVERHEAD_S = (time.perf_counter() - t0) / reps
    return _DISPATCH_OVERHEAD_S


def auto_chunk_bytes(
    *,
    device=None,
    overhead_frac: float = 0.02,
    assumed_gbps: float = 1.0,
) -> int:
    """Adaptive chunk size: device memory budget + measured dispatch
    overhead, replacing the fixed 4 MiB default (DESIGN.md §11).

    Two constraints pick the size:

      * overhead floor — the one-time dispatch-overhead probe bounds the
        per-chunk fixed cost; the chunk must be big enough that this cost is
        <= ``overhead_frac`` of the chunk's scan time at a conservative
        ``assumed_gbps`` streaming rate;
      * memory ceiling — the streaming working set is ~9.5 device bytes per
        streamed byte (StreamScanner.device_bytes_per_chunk), so the chunk
        must keep that working set inside a fraction of the device's free
        memory (``memory_stats`` when the backend reports it, a conservative
        512 MiB budget otherwise — CPU backends are host-RAM-backed).

    The result is clamped to [MIN_CHUNK_BYTES, MAX_CHUNK_BYTES] and rounded
    to the EPSMc beta block.
    """
    dev = device
    if dev is None:
        dev = jax.local_devices()[0]
    stats = {}
    try:
        stats = dev.memory_stats() or {}
    except Exception:  # backends without memory introspection
        stats = {}
    limit = stats.get("bytes_limit")
    if limit:
        free = max(int(limit) - int(stats.get("bytes_in_use", 0)), limit // 8)
        budget = free // 4
    else:
        budget = 512 << 20
    mem_cap = budget // 10  # ~9.5 working-set bytes per streamed byte
    floor = int(
        _dispatch_overhead_s() / overhead_frac * assumed_gbps * 1e9
    )
    chunk = max(DEFAULT_CHUNK_BYTES, floor)
    chunk = max(MIN_CHUNK_BYTES, min(chunk, mem_cap, MAX_CHUNK_BYTES))
    return _round_up(chunk, EPSMC_BETA)

def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


class Compressed:
    """Marks a byte source as gzip/zstd frames to decompress on the fly.

    ``source`` may be compressed bytes, a binary file-like, or an iterator
    of frames (e.g. one gzip member / zstd frame per cold-storage object) —
    concatenated frames are legal in both formats and decode as one logical
    stream.  ``codec`` is "gzip", "zstd", or "auto" (sniff the first frame's
    magic).  zstd needs the `zstandard` package; its absence raises only
    when a zstd source is actually opened."""

    def __init__(self, source, codec: str = "auto"):
        if codec not in ("auto", "gzip", "zstd"):
            raise ValueError(f"unknown codec {codec!r}")
        self.source = source
        self.codec = codec


def _raw_pieces(source) -> Iterator[bytes]:
    """COMPRESSED byte pieces of a Compressed source's underlying stream."""
    if isinstance(source, (bytes, bytearray, memoryview)):
        yield bytes(source)
        return
    if hasattr(source, "read"):
        while True:
            b = source.read(_READ_BYTES)
            if not b:
                return
            yield bytes(b)
        return
    for piece in source:
        if isinstance(piece, np.ndarray):
            piece = piece.tobytes()
        yield bytes(piece)


_GZIP_MAGIC = b"\x1f\x8b"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _chain_head(head: bytes, rest) -> Iterator[bytes]:
    if head:
        yield head
    yield from rest


def _new_decompressor(codec: str):
    if codec == "gzip":
        import zlib

        return zlib.decompressobj(wbits=16 + zlib.MAX_WBITS)
    try:
        import zstandard
    except ImportError as e:  # gated dep: only zstd sources need it
        raise RuntimeError(
            "zstd-compressed sources need the `zstandard` package "
            "(pip install zstandard), which is not installed"
        ) from e
    return zstandard.ZstdDecompressor().decompressobj()


def _decompressed_chunks(c: Compressed) -> Iterator[np.ndarray]:
    """Incremental multi-frame decompression: O(compressed piece + emitted
    chunk) host memory, frames restarted via each decompressor's
    eof/unused_data contract (zlib and zstandard expose the same one)."""
    codec = c.codec
    d = None
    pieces = _raw_pieces(c.source)
    head = b""
    if codec == "auto":
        # a read()/iterator may legally deliver < 4 bytes: buffer until the
        # longest magic is decidable before sniffing
        for piece in pieces:
            head += piece
            if len(head) >= len(_ZSTD_MAGIC):
                break
        codec = "zstd" if head[: len(_ZSTD_MAGIC)] == _ZSTD_MAGIC else "gzip"
    for data in _chain_head(head, pieces):
        while data:
            if d is None:
                d = _new_decompressor(codec)
            out = d.decompress(data)
            if out:
                yield np.frombuffer(out, np.uint8)
            if d.eof:  # frame boundary: restart on the leftover bytes
                data = d.unused_data
                d = None
            else:
                data = b""
    if d is not None and not d.eof:
        raise ValueError(f"truncated {codec} stream")


def _as_chunks(source) -> Iterator[np.ndarray]:
    """Normalize any byte source into an iterator of host uint8 arrays."""
    if isinstance(source, Compressed):
        yield from _decompressed_chunks(source)
        return
    if isinstance(source, str):
        source = source.encode("utf-8", errors="surrogateescape")
    if isinstance(source, (bytes, bytearray, memoryview)):
        yield np.frombuffer(bytes(source), np.uint8)
        return
    if isinstance(source, np.ndarray):
        a = source.reshape(-1)
        yield a if a.dtype == np.uint8 else a.astype(np.uint8)
        return
    if isinstance(source, jax.Array):
        yield np.asarray(jax.device_get(source)).astype(np.uint8).reshape(-1)
        return
    if hasattr(source, "read"):
        while True:
            b = source.read(_READ_BYTES)
            if not b:
                return
            yield np.frombuffer(bytes(b), np.uint8)
    else:
        for piece in source:
            yield from _as_chunks(piece)


@functools.lru_cache(maxsize=None)
def _jitted_count_step(fused: bool, shared: bool = True):
    """Jit the chunk step lazily: donating the count accumulator lets XLA
    reuse its buffer across chunks on accelerator backends (CPU ignores
    donation and warns, so it is gated on the backend) — and the backend
    query must NOT run at import time, or merely importing repro.core would
    initialize XLA before the user can configure it."""
    donate = (0,) if jax.default_backend() != "cpu" else ()
    step = _fused_count_step if fused else _count_step
    return functools.partial(
        jax.jit, static_argnames=("ov", "k", "shared"), donate_argnums=donate
    )(functools.partial(step, shared=shared))


def _fused_count_step(
    counts, window, length, prev_ov, plans, *, ov: int, k, shared: bool = True
):
    """One streaming chunk, seam correction FUSED into the scan: the
    ``end_min=prev_ov`` gate inside every matcher keeps exactly the
    occurrences whose END falls in the newly-streamed region, replacing the
    reference path's separate overlap-prefix subtraction (DESIGN.md §11
    proves the two produce identical integers).  One count_many — i.e. one
    fingerprint-bank pass and one shared compaction — per chunk."""
    del ov  # the fused gate needs no prefix sub-index
    idx = engine.build_index(window[None, :], jnp.asarray(length)[None])
    return counts + engine.count_many(
        idx, plans, k=k, end_min=prev_ov, shared=shared
    )[0]


def _count_step(
    counts, window, length, prev_ov, plans, *, ov: int, k, shared: bool = True
):
    """Reference two-pass chunk step: full-window counts minus
    overlap-prefix counts.  Kept as the fallback and the oracle the fused
    paths (``_fused_count_step`` and the megascan kernel) are pinned
    against in tests/test_stream.py and tests/test_megascan.py.

    ``window`` is (N,) uint8 with ``length`` valid bytes, the first
    ``prev_ov`` of which were carried from the previous window (0 for the
    first chunk).  The subtraction removes exactly the occurrences whose
    window lies entirely inside the carried prefix — the ones the previous
    chunk already counted — so the sum over chunks is the whole-text count.
    The prefix sub-index spans ``ov`` (static, <= max_m + beta - 2) bytes:
    its cost is noise next to the O(N) window scan, and both run in this one
    dispatch."""
    idx = engine.build_index(window[None, :], jnp.asarray(length)[None])
    c = engine.count_many(idx, plans, k=k, shared=shared)
    if ov:
        pre_idx = engine.build_index(
            window[None, :ov], jnp.minimum(jnp.asarray(prev_ov), length)[None]
        )
        c = c - engine.count_many(pre_idx, plans, k=k, shared=shared)
    return counts + c[0]


@functools.lru_cache(maxsize=None)
def _jitted_kernel_step(spec):
    """Chunk step through the fused Pallas megakernel (kernels/megascan):
    ONE pallas dispatch stages each tile once and answers every group, the
    k-mismatch accumulator, and the seam gate together.  ``spec`` is the
    static MegaSpec; the (length, prev_ov) scalars are traced operands, so
    one compilation serves every chunk."""
    from repro.kernels.megascan import megascan_count_window

    def step(counts, window, length, prev_ov, plans):
        return counts + megascan_count_window(
            window, plans, spec, length=length, prev_ov=prev_ov
        )

    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(step, donate_argnums=donate)


@functools.partial(jax.jit, static_argnames=("k", "fused"))
def _mask_step(window, length, prev_ov, plans, *, k, fused: bool = True):
    """(P_total, N) bool match-start mask for one chunk, de-duplicated at the
    seam: a start survives iff its occurrence ENDS at or past ``prev_ov``
    (ends inside the carried prefix belong to the previous chunk).  The
    fused form pushes that gate into the matchers' candidate masks
    (``end_min``); the reference form post-filters — bit-identical."""
    idx = engine.build_index(window[None, :], jnp.asarray(length)[None])
    if fused:
        return engine.match_many(idx, plans, k=k, end_min=prev_ov)[0]
    mask = engine.match_many(idx, plans, k=k)[0]
    pos = jnp.arange(window.shape[0], dtype=jnp.int32)
    keeps = []
    for plan in plans:
        keep = pos + (plan.m - 1) >= prev_ov
        keeps.append(
            jnp.broadcast_to(keep[None, :], (plan.n_patterns, window.shape[0]))
        )
    return mask & jnp.concatenate(keeps, axis=0)


class StreamScanner:
    """Chunked, double-buffered, exact streaming matcher for a plan set.

    Device memory is O(chunk_bytes) regardless of input length; every chunk
    costs exactly one jitted dispatch (``dispatch_count`` audits this).
    Pattern rows are in plan-concatenated order, as everywhere in the
    engine; ``order`` maps them back to the original pattern sequence.

    ``k`` overrides the per-plan mismatch budget exactly like
    ``engine.count_many(..., k=)``; None runs each plan at the budget it was
    compiled for.

    ``chunk_bytes`` may be an int or ``"auto"`` (the default): auto picks
    the window from the device memory budget and a one-time measured
    dispatch-overhead probe (:func:`auto_chunk_bytes`) and logs the chosen
    value; the resolved size is ``self.chunk_bytes``.

    ``fused`` (default True) runs each chunk with the seam correction fused
    into the matchers (``count_many(..., end_min=prev_ov)`` — one scan, no
    overlap-prefix sub-index); False keeps the reference two-pass step,
    bit-identical by DESIGN.md §11.  ``use_kernel`` additionally routes
    counting through the fused Pallas megakernel (kernels/megascan) when
    the plan set is kernel-eligible — ineligible sets fall back to the
    pure-JAX fused path (logged), never to different results.

    ``device`` pins every dispatch (windows, accumulator, plan state) to one
    local device; the sharded scanner (core/shard_stream.py) uses this to
    fan shards out over the fleet's devices, whose async dispatch queues
    then drain concurrently.  None keeps jax's default placement.

    ``count_many``/``masks``/``positions_many`` accept ``prefix``/``start``
    to scan a mid-stream RANGE of a larger logical stream: ``start`` is the
    global byte offset of the source's first byte and ``prefix`` the up-to-
    ``overlap`` bytes immediately before it (its occurrences-ending-inside
    belong to whoever scanned the preceding range — the shard seam is just
    a second-level window seam, DESIGN.md §10).  ``start - len(prefix)``
    must sit on a beta block boundary so chunk-local aligned block
    fingerprints still coincide with the global ones.

    ``recorder`` attaches a :class:`~repro.obs.recorder.Recorder` (DESIGN.md
    §13): every chunk then traces a ``host_prep`` span (source read /
    decompress / window assembly), a ``device_put`` span, and a fenced
    ``dispatch`` span (the jitted scan, seam fusion included), plus
    ``dispatches``/``bytes_scanned`` counters.  The default is the module's
    disabled recorder — no spans, no fencing, the double-buffered pipeline
    untouched — whose only effect is feeding instant events (auto-chunk
    probe, kernel fallback, stragglers) to the module logger.  ``lane``
    names this scanner's trace track (the sharded scanner sets it).

    ``watchdog`` arms a :class:`~repro.dist.fault_tolerance.StepWatchdog`
    around every chunk's HOST step — source read, decompression, window
    assembly — the part where a slow disk or object store stalls (device
    dispatch is asynchronous and surfaces at the final sync, not here).  ``policy="raise"`` turns a stalled chunk into a
    ``StragglerAbort`` a supervisor can act on; ``on_straggler(event)``
    observes flagged chunks under the non-raising policies (the elastic
    sharded scanner sheds a straggling shard's trailing range there,
    DESIGN.md §12).
    """

    def __init__(
        self,
        plans: Sequence[PatternPlan],
        chunk_bytes: Union[int, str] = "auto",
        *,
        k: Optional[int] = None,
        device=None,
        fused: bool = True,
        shared: bool = True,
        use_kernel: bool = False,
        watchdog=None,
        on_straggler=None,
        recorder: Optional[Recorder] = None,
        lane: Optional[str] = None,
    ):
        self.plans = tuple(plans)
        if not self.plans:
            raise ValueError("StreamScanner needs at least one PatternPlan")
        # rec is consulted unconditionally on every chunk (spans + counters);
        # the module default is the disabled recorder with a logging sink
        # (DESIGN.md §13).  ``lane`` names this scanner's trace track — the
        # sharded scanner sets it so stolen ranges stay attributed.
        self.rec = _DEFAULT_REC if recorder is None else recorder
        self.lane = lane
        self.device = device
        if device is not None:
            self.plans = engine.replicate_plans(self.plans, device)
        self.k = k
        self.fused = bool(fused)
        # shared=False pins the pre-fusion per-group engine path (each group
        # pays its own fingerprint pass + compaction — count_many shared=False);
        # the megascan benchmark's per-group baseline.
        self.shared = bool(shared)
        self.spec = None
        if use_kernel:
            from repro.kernels.megascan import build_mega_spec

            self.spec = build_mega_spec(self.plans, k=k)
            if self.spec is None:
                self.rec.event(
                    "kernel_fallback", lane=self.lane,
                    reason="megascan ineligible for this plan set; "
                    "using the pure-JAX fused path",
                )
        if chunk_bytes == "auto":
            chunk_bytes = auto_chunk_bytes(device=device)
            self.rec.event(
                "auto_chunk", lane=self.lane, chunk_bytes=int(chunk_bytes),
                dispatch_overhead_us=round(1e6 * _dispatch_overhead_s(), 1),
            )
        self.chunk_bytes = int(chunk_bytes)
        self.max_m = max(p.m for p in self.plans)
        # overlap >= max_m - 1 carries every possibly-straddling occurrence
        # start; rounding up to the beta block keeps each window's start on
        # a global beta boundary, so chunk-local aligned block fingerprints
        # coincide with the global ones (EPSMc block-phase carry).
        self.overlap = _round_up(self.max_m - 1, EPSMC_BETA)
        window = max(self.chunk_bytes, self.overlap + EPSMC_BETA)
        self.window_bytes = _round_up(window, EPSMC_BETA)
        self.step_bytes = self.window_bytes - self.overlap
        self.n_patterns = sum(p.n_patterns for p in self.plans)
        self.order = engine.plan_order(self.plans)
        self.dispatch_count = 0
        self.watchdog = watchdog
        self.on_straggler = on_straggler

    # -- host-side re-chunking ---------------------------------------------

    def _injection(self, prefix, start: int) -> Tuple[np.ndarray, int]:
        """Validate a mid-stream (prefix, start) injection; returns the
        normalized carry array and the global position of the first window."""
        if prefix is None:
            carry = np.zeros(0, np.uint8)
        else:
            carry = np.ascontiguousarray(
                np.asarray(jax.device_get(prefix)).reshape(-1), np.uint8
            )
        if len(carry) > self.overlap:
            raise ValueError(
                f"injected prefix ({len(carry)} B) exceeds the scanner "
                f"overlap ({self.overlap} B)"
            )
        base = int(start) - len(carry)
        if base % EPSMC_BETA:
            raise ValueError(
                "start - len(prefix) must be a multiple of EPSMC_BETA "
                f"({EPSMC_BETA}) to preserve the global block phase; got "
                f"start={start}, len(prefix)={len(carry)}"
            )
        return carry, base

    def _windows(
        self, source, *, prefix=None, start: int = 0
    ) -> Iterator[Tuple[np.ndarray, int, int, int]]:
        """Yield (window (N,) uint8, valid_len, carry_len, base): fixed-
        capacity host windows where window[:carry_len] re-feeds the previous
        window's tail and ``base`` is the global position of window[0].
        ``prefix``/``start`` seed the first window's carry for mid-stream
        ranges (the first chunk's seam subtraction then removes occurrences
        the preceding range already owned)."""
        N, ov = self.window_bytes, self.overlap
        pieces: deque = deque()
        have = 0
        carry, base = self._injection(prefix, start)
        exhausted = False
        it = _as_chunks(source)
        while True:
            while not exhausted and have < N - len(carry):
                try:
                    piece = next(it)
                except StopIteration:
                    exhausted = True
                    break
                if len(piece):
                    pieces.append(piece)
                    have += len(piece)
            new_len = min(have, N - len(carry))
            if new_len == 0:
                return  # nothing newly streamed: no window to emit
            win = np.zeros(N, np.uint8)
            win[: len(carry)] = carry
            filled = len(carry)
            need = new_len
            while need:
                piece = pieces.popleft()
                take = min(len(piece), need)
                win[filled : filled + take] = piece[:take]
                if take < len(piece):
                    pieces.appendleft(piece[take:])
                filled += take
                need -= take
            have -= new_len
            L = len(carry) + new_len
            yield win, L, len(carry), base
            carry = win[max(0, L - ov) : L].copy() if ov else carry
            base += L - len(carry)

    def _steps(self, source, *, prefix=None, start: int = 0):
        """The `_windows` iterator with each window's PRODUCTION (source
        read, decompress, assembly) wrapped in a ``host_prep`` recorder span
        and, when a watchdog is armed, timed for straggling: the stall site
        for slow storage.  A flagged chunk either raises (policy="raise") or
        is recorded as a ``straggler`` event and reported to
        ``on_straggler``."""
        rec, lane = self.rec, self.lane
        wd = self.watchdog
        it = self._windows(source, prefix=prefix, start=start)
        step = 0
        while True:
            if wd is not None:
                wd.start_step(step)
            try:
                with rec.span("host_prep", lane=lane, step=step) as sp:
                    win, L, carry_len, base = next(it)
                    sp.set(bytes=int(L) - int(carry_len))
            except StopIteration:
                if wd is not None:
                    wd.end_step()  # close the pair; an instant EOF never flags
                return
            if wd is not None and wd.end_step() is not None:
                ev = wd.events[-1]
                rec.event(
                    "straggler", lane=lane, step=ev.step,
                    duration_s=round(ev.duration_s, 6),
                    median_s=round(ev.median_s, 6),
                    factor=round(ev.factor, 2),
                )
                if self.on_straggler is not None:
                    self.on_straggler(ev)
            step += 1
            yield win, L, carry_len, base

    # -- device loop --------------------------------------------------------

    def _put(self, win):
        """Host->device window transfer under a ``device_put`` span.  The
        transfer itself is async; the fence (enabled recorder only) charges
        the copy to this span instead of the next dispatch."""
        with self.rec.span(
            "device_put", lane=self.lane, bytes=int(win.nbytes)
        ) as sp:
            return sp.fence(jax.device_put(win, self.device))

    def _dispatch_count(self, counts, window_dev, length, prev_ov):
        self.dispatch_count += 1
        new_bytes = int(length) - int(prev_ov)
        with self.rec.span(
            "dispatch", lane=self.lane, chunk=self.dispatch_count,
            bytes=new_bytes,
        ) as sp:
            if self.spec is not None:
                counts = _jitted_kernel_step(self.spec)(
                    counts, window_dev, length, prev_ov, self.plans
                )
            else:
                counts = _jitted_count_step(self.fused, self.shared)(
                    counts, window_dev, length, prev_ov, self.plans,
                    ov=self.overlap, k=self.k,
                )
            # seam fusion (end_min gate / overlap sub-index) runs inside this
            # same dispatch; the fence makes the span cover the device work
            sp.fence(counts)
        self.rec.count("dispatches")
        self.rec.count("bytes_scanned", new_bytes)
        return counts

    def _zero_counts(self):
        z = jnp.zeros((self.n_patterns,), jnp.int32)
        return z if self.device is None else jax.device_put(z, self.device)

    def count_device(self, source, *, prefix=None, start: int = 0):
        """Device-resident (P_total,) int32 count accumulator, NOT synced —
        the sharded scanner enqueues every shard's chunks this way and pays
        one collective merge instead of a per-shard host round-trip.

        Double-buffered: the (i+1)-th window's host->device transfer is
        issued before the i-th window's (asynchronously dispatched) compute
        is consumed, and nothing here waits on device results at all."""
        counts = self._zero_counts()
        pending = None
        for win, L, carry_len, _base in self._steps(
            source, prefix=prefix, start=start
        ):
            dev = self._put(win)
            if pending is not None:
                counts = self._dispatch_count(counts, *pending)
            pending = (dev, np.int32(L), np.int32(carry_len))
        if pending is not None:
            counts = self._dispatch_count(counts, *pending)
        return counts

    def count_many(self, source, *, prefix=None, start: int = 0) -> np.ndarray:
        """int32 (P_total,) exact occurrence counts over the whole stream
        (or, with ``prefix``/``start``, over one mid-stream range — counting
        exactly the occurrences whose END lies inside it)."""
        return np.asarray(
            jax.device_get(self.count_device(source, prefix=prefix, start=start))
        )

    def any_many(self, source) -> np.ndarray:
        """bool (P_total,) — does each pattern occur anywhere in the stream?"""
        return self.count_many(source) > 0

    def contains_any(self, source, *, sync_every: int = 8) -> bool:
        """Scalar verdict with early exit: the accumulator is polled every
        ``sync_every`` chunks so a hit near the head of a long stream stops
        the scan without draining the source."""
        counts = self._zero_counts()
        pending = None
        chunks = 0
        for win, L, carry_len, _base in self._steps(source):
            dev = self._put(win)
            if pending is not None:
                counts = self._dispatch_count(counts, *pending)
                chunks += 1
                if chunks % sync_every == 0 and bool(counts.sum() > 0):
                    return True
            pending = (dev, np.int32(L), np.int32(carry_len))
        if pending is not None:
            counts = self._dispatch_count(counts, *pending)
        return bool(np.asarray(jax.device_get(counts)).sum() > 0)

    def masks(
        self, source, *, prefix=None, start: int = 0
    ) -> Iterator[Tuple[int, int, np.ndarray]]:
        """Yield (base, new_start, (P_total, L) bool) per chunk: the seam-
        deduped match-start mask of the chunk's valid bytes.  A start at
        column j is global position base + j; every occurrence appears in
        exactly one yielded mask.  ``new_start`` is the carried-prefix
        length (starts before new_start - max_m + 1 are always False).
        With ``prefix``/``start``, bases are global stream positions and
        occurrences ending before ``start`` are dropped (previous range's)."""
        pending = None
        for win, L, carry_len, base in self._steps(
            source, prefix=prefix, start=start
        ):
            dev = self._put(win)
            if pending is not None:
                yield self._flush_mask(*pending)
            pending = (dev, np.int32(L), np.int32(carry_len), base, L)
        if pending is not None:
            yield self._flush_mask(*pending)

    def _flush_mask(self, dev, length, prev_ov, base, L):
        self.dispatch_count += 1
        new_bytes = int(length) - int(prev_ov)
        with self.rec.span(
            "dispatch", lane=self.lane, chunk=self.dispatch_count,
            bytes=new_bytes,
        ) as sp:
            mask = sp.fence(_mask_step(
                dev, length, prev_ov, self.plans, k=self.k, fused=self.fused
            ))
        self.rec.count("dispatches")
        self.rec.count("bytes_scanned", new_bytes)
        return base, int(prev_ov), np.asarray(jax.device_get(mask))[:, :L]

    def positions_many(
        self, source, *, prefix=None, start: int = 0
    ) -> List[np.ndarray]:
        """Per-pattern sorted global occurrence start positions (host side;
        output-sized host memory, still O(chunk) device memory)."""
        out: List[List[np.ndarray]] = [[] for _ in range(self.n_patterns)]
        for base, _new_start, mask in self.masks(source, prefix=prefix, start=start):
            for p_i in range(self.n_patterns):
                (loc,) = np.nonzero(mask[p_i])
                if len(loc):
                    out[p_i].append(loc.astype(np.int64) + base)
        return [
            np.concatenate(o) if o else np.zeros(0, np.int64) for o in out
        ]

    # -- accounting ---------------------------------------------------------

    @property
    def device_bytes_per_chunk(self) -> int:
        """Estimated peak device working set per chunk: window text (1) +
        packed u32 view (4) + block fingerprints (0.5) + one fingerprint
        temporary (4) per byte, plus the plan LUTs."""
        per_byte = self.window_bytes + self.overlap
        luts = 0
        for p in self.plans:
            luts += (1 << p.kbits)  # lut_any
            if p.lut_pid is not None:
                luts += 4 * (1 << p.kbits)
            if p.lut_bits is not None:
                luts += 4 * p.lut_bits.shape[-1] * (1 << p.kbits)
            if p.relaxed_lut is not None:
                luts += (1 << p.kbits)
        return int(9.5 * per_byte) + luts


# ---------------------------------------------------------------------------
# Convenience wrappers (the epsm.find/count stream= escape hatch lands here)
# ---------------------------------------------------------------------------

def stream_count(
    source,
    patterns: Sequence,
    *,
    k: int = 0,
    chunk_bytes: Union[int, str] = "auto",
    use_kernel: bool = False,
) -> np.ndarray:
    """int32 (P,) exact (or <= k-mismatch) counts in ORIGINAL pattern order.
    ``chunk_bytes="auto"`` (default) sizes the window adaptively."""
    plans = engine.compile_patterns_cached(list(patterns), k=k)
    sc = StreamScanner(plans, chunk_bytes, k=k, use_kernel=use_kernel)
    counts = sc.count_many(source)
    out = np.zeros_like(counts)
    out[sc.order] = counts
    return out


def find_stream(
    source,
    pattern,
    *,
    k: int = 0,
    chunk_bytes: Union[int, str] = "auto",
) -> np.ndarray:
    """Whole-stream bool match-start mask for ONE pattern, assembled on the
    host chunk by chunk (host memory is O(n); device stays O(chunk))."""
    plans = engine.compile_patterns_cached([pattern], k=k)
    sc = StreamScanner(plans, chunk_bytes, k=k)
    out = np.zeros(sc.window_bytes, bool)
    n = 0
    for base, _new_start, mask in sc.masks(source):
        end = base + mask.shape[1]
        if end > len(out):
            out = np.resize(out, max(2 * len(out), end))
            out[n:] = False
        out[base:end] |= mask[0]
        n = max(n, end)
    return out[:n]
