"""Core EPSM library: the paper's contribution as composable JAX modules."""

from repro.core.epsm import (
    EPSMA_MAX,
    EPSMB_MAX,
    EPSMC_BETA,
    EPSMC_KBITS,
    count,
    count_jit,
    epsma,
    epsmb,
    epsmc,
    find,
    find_jit,
    positions,
    select_algo,
)
from repro.core.engine import (
    FingerprintBank,
    PatternPlan,
    TextIndex,
    any_many,
    build_index,
    compile_patterns,
    count_many,
    match_many,
)
from repro.core.multipattern import PatternSet, contains_any, count_multi, find_multi
from repro.core.stream import Compressed, StreamScanner, find_stream, stream_count
from repro.core.shard_stream import (
    PartialScanResult,
    ShardedStreamScanner,
    StealEvent,
    shard_stream_count,
)
from repro.core.remote_source import FakeObjectStore, RemoteRangeReader
from repro.core.baselines import BASELINES, naive_np

__all__ = [
    "Compressed",
    "FakeObjectStore",
    "FingerprintBank",
    "PartialScanResult",
    "PatternPlan",
    "RemoteRangeReader",
    "ShardedStreamScanner",
    "StealEvent",
    "StreamScanner",
    "shard_stream_count",
    "TextIndex",
    "any_many",
    "build_index",
    "compile_patterns",
    "count_many",
    "match_many",
    "EPSMA_MAX",
    "EPSMB_MAX",
    "EPSMC_BETA",
    "EPSMC_KBITS",
    "BASELINES",
    "PatternSet",
    "contains_any",
    "count",
    "count_jit",
    "count_multi",
    "epsma",
    "epsmb",
    "epsmc",
    "find",
    "find_jit",
    "find_multi",
    "find_stream",
    "naive_np",
    "stream_count",
    "positions",
    "select_algo",
]
