"""Byte-packing utilities for packed string matching.

The paper packs alpha = w/log(sigma) characters into one machine word and
compares them in bulk.  On TPU the analogous trick is packing 4 consecutive
uint8 characters into one int32 *lane* so that a single 32-bit vector compare
tests a 4-gram at every position (the TPU-native analogue of SSE's
``_mm_mpsadbw_epu8`` 4-byte anchor used by EPSMb).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

# Number of bytes packed into one 32-bit lane.  This mirrors the paper's
# 4-byte mpsadbw anchor (wsmatch matches the length-4 prefix of the pattern).
PACK = 4


def as_u8(x) -> jnp.ndarray:
    """Coerce bytes / str / ndarray to a uint8 jnp array."""
    if isinstance(x, str):
        x = x.encode("utf-8", errors="surrogateescape")
    if isinstance(x, (bytes, bytearray, memoryview)):
        x = np.frombuffer(bytes(x), dtype=np.uint8)
    arr = jnp.asarray(x)
    if arr.dtype != jnp.uint8:
        arr = arr.astype(jnp.uint8)
    return arr


def as_u8_np(x) -> np.ndarray:
    """Host-side sibling of :func:`as_u8`: coerce to a NUMPY uint8 array
    without ever touching a device.  Plan compilation is a host loop over
    up to ~10^5 patterns — one jnp round-trip per pattern is ~16s of pure
    device_put at dictionary scale, vs milliseconds staying on host."""
    if isinstance(x, str):
        x = x.encode("utf-8", errors="surrogateescape")
    if isinstance(x, (bytes, bytearray, memoryview)):
        return np.frombuffer(bytes(x), dtype=np.uint8)
    if isinstance(x, np.ndarray):
        return x if x.dtype == np.uint8 else x.astype(np.uint8)
    import jax

    arr = np.asarray(jax.device_get(x))
    return arr if arr.dtype == np.uint8 else arr.astype(np.uint8)


def shift_left(x: jnp.ndarray, j: int) -> jnp.ndarray:
    """Return y with y[i] = x[i + j] (zero padded at the tail).

    This is the vector analogue of the paper's ``s_j << j`` used by EPSMa to
    align per-character equality masks.  Implemented as a pad+slice so it
    lowers to a cheap static slice rather than a gather.
    """
    if j == 0:
        return x
    n = x.shape[-1]
    pad = [(0, 0)] * (x.ndim - 1) + [(0, j)]
    return jnp.pad(x, pad)[..., j : j + n]


def pack_u32(text_u8: jnp.ndarray) -> jnp.ndarray:
    """w[i] = t[i] | t[i+1]<<8 | t[i+2]<<16 | t[i+3]<<24  (little endian).

    One uint32 lane now holds the 4-gram starting at position i.  Tail lanes
    (i > n-4) contain zero-padded garbage; callers mask starts > n-m anyway.
    """
    t = text_u8.astype(jnp.uint32)
    w = t
    for j in range(1, PACK):
        w = w | (shift_left(t, j) << (8 * j))
    return w


def count_zero_bytes_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Number of zero bytes (0..4) in each uint32 lane.

    This is the packed agreement counter of the k-mismatch path
    (repro.approx): XOR a packed text word against a packed pattern word and
    the agreeing byte lanes are exactly the zero bytes of the result — a
    vectorized popcount-style sum, four byte compares folded into one 32-bit
    lane op per position (cf. Giaquinta, Grabowski & Fredriksson,
    arXiv:1211.5433, where k-mismatch search in packed text reduces to
    per-position symbol-agreement counting over words).
    """
    x = x.astype(jnp.uint32)
    acc = jnp.zeros(x.shape, jnp.int32)
    for s in (0, 8, 16, 24):
        acc = acc + (((x >> jnp.uint32(s)) & jnp.uint32(0xFF)) == 0).astype(
            jnp.int32
        )
    return acc


def pack_word_u32(four_bytes: jnp.ndarray) -> jnp.ndarray:
    """Pack exactly 4 uint8 values into a scalar uint32 (little endian)."""
    b = four_bytes.astype(jnp.uint32)
    return b[0] | (b[1] << 8) | (b[2] << 16) | (b[3] << 24)


def valid_start_mask(n: int, m: int) -> jnp.ndarray:
    """Boolean mask of positions where a length-m occurrence can start."""
    return jnp.arange(n) <= (n - m)


FP_MULT = np.uint32(2654435761)  # Knuth's multiplicative-hash constant
# fixed odd salts mixing the packed words of one window into one fingerprint
WORD_SALTS = np.uint32(
    np.random.RandomState(0xE95).randint(1, 2**30, size=8) * 2 + 1
)


def fp_accum_word(v: jnp.ndarray, word: jnp.ndarray, salt_index: int) -> jnp.ndarray:
    """Add one salted packed-word term to a running window-fingerprint sum.

    The ONE definition of how a packed word enters the window fingerprint —
    shared by the engine's matchers, the FingerprintBank prefix accumulation
    (engine.py), and the Pallas multipattern kernel, so every consumer stays
    keyed to the same LUTs.  uint32 adds wrap mod 2^32, making the sum
    associative/commutative — the property the bank's prefix sharing needs."""
    return v + word * jnp.uint32(int(WORD_SALTS[salt_index]))


def fp_finalize(v: jnp.ndarray, kbits: int) -> jnp.ndarray:
    """Final multiplicative mix + top-bits truncation of a salted sum."""
    return ((v * jnp.uint32(int(FP_MULT))) >> jnp.uint32(32 - kbits)).astype(
        jnp.int32
    )


def fingerprint_weights(beta: int, seed: int = 12345) -> jnp.ndarray:
    """Fixed pseudo-random odd int32 weights for the multiplicative hash.

    The paper fingerprints 8-byte blocks with the crc32 instruction; TPU has
    no CRC unit, so we use h(block) = (block . r) mod 2^32 masked to k bits,
    with fixed odd weights r.  The dot product maps onto the MXU.
    """
    rng = np.random.RandomState(seed)
    w = rng.randint(1, 2**31 - 1, size=(beta,)).astype(np.int64) * 2 + 1
    return jnp.asarray(w & 0x7FFFFFFF, dtype=jnp.int32)


def hash_blocks(blocks_u8: jnp.ndarray, weights: jnp.ndarray, kbits: int) -> jnp.ndarray:
    """k-bit fingerprints of (..., beta) uint8 blocks via int32 dot.

    int32 overflow wraps (two's complement) under XLA, which is exactly the
    mod-2^32 arithmetic the multiplicative hash wants.
    """
    h = jnp.einsum(
        "...b,b->...",
        blocks_u8.astype(jnp.int32),
        weights.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return (h & ((1 << kbits) - 1)).astype(jnp.int32)
