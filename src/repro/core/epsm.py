"""EPSM — Exact Packed String Matching (Faro & Kulekci, 2012) in JAX.

The paper dispatches on pattern length m:

  * EPSMa (0 < m < 4):  per-character broadcast compare + shifted AND
                        (wscmp = cmpeq_epi8 + movemask on SSE).
  * EPSMb (4 <= m < 16): packed 4-gram anchor compare + verification
                        (wsmatch = mpsadbw on SSE).
  * EPSMc (m >= 16):    block fingerprint filter (wscrc = crc32_u64 on SSE)
                        with stride (floor(m/beta)-1)*beta, then verification.

TPU adaptation (see DESIGN.md §2): SSE's 16-lane word becomes a whole vector
tile; wsmatch becomes a pack-4-bytes-into-int32-lane single compare; wscrc
becomes a multiplicative matmul hash; occurrence lists become dense boolean
match-start masks; the 2^k bucket table of EPSMc becomes a dense
fingerprint-vs-offset comparison (noff <= m-beta+1 is tiny, and dense compare
is the TPU idiom — documented as adaptation #6).

All functions return ``mask: bool[n]`` with mask[i] True iff an occurrence of
``pattern`` starts at text position i.  Everything is jit-compatible; pattern
length is static (part of the trace).

This module is the single-(text, pattern) reference layer.  The hot path for
multi-pattern / batched-text workloads is the explicit two-phase engine in
``repro.core.engine`` (DESIGN.md §7): a TextIndex packs and fingerprints the
text once, per-length-group PatternPlans carry the compiled pattern state,
and ``match_many`` answers P patterns x B texts per device dispatch.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import packing
from repro.core.packing import (
    PACK,
    as_u8,
    fingerprint_weights,
    hash_blocks,
    pack_u32,
    pack_word_u32,
    shift_left,
    valid_start_mask,
)

# ---------------------------------------------------------------------------
# Paper regime thresholds (Section 3: EPSMa for 0<m<4, EPSMb for 4<=m<16,
# EPSMc for m>=16).
# ---------------------------------------------------------------------------
EPSMA_MAX = 4
EPSMB_MAX = 16
# EPSMc fingerprint block width.  The paper's wscrc is _mm_crc32_u64, i.e. an
# 8-byte block; beta=8 also makes the strided-filter exact for every m >= 16
# (see DESIGN.md).  Must satisfy m >= 2*beta.
EPSMC_BETA = 8
EPSMC_KBITS = 11  # paper: k = 11


def _to_arrays(text, pattern):
    t = as_u8(text)
    p = as_u8(pattern)
    if p.ndim != 1 or t.ndim != 1:
        raise ValueError("text and pattern must be 1-D byte arrays")
    return t, p


# ---------------------------------------------------------------------------
# EPSMa — very short patterns: r = s_0 & (s_1 << 1) & ... & (s_{m-1} << (m-1))
# ---------------------------------------------------------------------------

def epsma(text, pattern) -> jnp.ndarray:
    """Shifted-AND of per-character equality masks (paper Fig. 1, top).

    s_j[i] = (t[i] == p[j]); match at i iff AND_j s_j[i+j].  On SSE each s_j
    covers alpha=16 positions; here one vector op covers the whole tile.
    The block-crossing checks of the paper (lines 13-14) are unnecessary:
    shift_left is a logical shift over the whole text, not per 16-byte block.
    """
    t, p = _to_arrays(text, pattern)
    n, m = t.shape[0], p.shape[0]
    if n < m:
        return jnp.zeros((n,), dtype=jnp.bool_)
    acc = jnp.ones((n,), dtype=jnp.bool_)
    for j in range(m):
        acc = acc & (shift_left(t, j) == p[j])
    return acc & valid_start_mask(n, m)


# ---------------------------------------------------------------------------
# EPSMb — short patterns: packed 4-gram anchor + verification
# ---------------------------------------------------------------------------

def epsmb(text, pattern) -> jnp.ndarray:
    """Packed-anchor filter (paper Fig. 1, middle).

    The SSE version matches the length-4 prefix of p at every offset of a
    16-byte window with one mpsadbw.  TPU version: pack every 4-gram of the
    text into an int32 lane (pack_u32) and compare against the packed 4-byte
    pattern prefix — one 32-bit vector compare tests four characters at every
    position.  Remaining m-4 characters are verified with shifted compares
    (the paper's "naive check", dense-masked because TPU prefers masks over
    branches).
    """
    t, p = _to_arrays(text, pattern)
    n, m = t.shape[0], p.shape[0]
    if m < PACK:
        return epsma(t, p)
    if n < m:
        return jnp.zeros((n,), dtype=jnp.bool_)
    w = pack_u32(t)
    anchor = pack_word_u32(p[:PACK])
    acc = w == anchor
    # Verify the tail (chars 4..m-1).  Packed 4-gram steps where possible:
    j = PACK
    while j + PACK <= m:
        acc = acc & (shift_left(w, j) == pack_word_u32(p[j : j + PACK]))
        j += PACK
    for jj in range(j, m):
        acc = acc & (shift_left(t, jj) == p[jj])
    return acc & valid_start_mask(n, m)


# ---------------------------------------------------------------------------
# EPSMc — medium patterns: fingerprint filter + verification
# ---------------------------------------------------------------------------

def _epsmc_stride(m: int, beta: int) -> int:
    """Inspected-block stride in characters: (floor(m/beta) - 1) * beta.

    Exactness: every occurrence window [x, x+m) contains an aligned beta-block
    whose start lies in [x, x+m-beta]; consecutive inspected aligned starts
    are (floor(m/beta)-1)*beta <= m-beta apart, and any window of length
    m-beta+1 >= stride+1 contains one inspected start.  Requires m >= 2*beta.
    """
    q = m // beta
    return max(1, q - 1) * beta


def epsmc(
    text,
    pattern,
    *,
    beta: int = EPSMC_BETA,
    kbits: int = EPSMC_KBITS,
    cand_frac: float = 0.04,
) -> jnp.ndarray:
    """Fingerprint filter (paper Fig. 1, bottom), MXU-hash variant.

    Preprocessing: k-bit fingerprints of all beta-wide pattern substrings
    (offsets 0..m-beta) registered in the paper's 2^k lookup table L.
    Search: fingerprint aligned text blocks at stride (floor(m/beta)-1)*beta
    via the strided-reshape view (no gather) + MXU matmul hash; probe L once
    per block; compact candidate BLOCKS with a fixed-size nonzero and verify
    all noff window offsets of each by static span slicing; one batched
    scatter publishes matches.  A dense verification branch (lax.cond) runs
    when candidates overflow the budget, so exactness never depends on the
    compaction heuristic.  This shape emerged from three measured §Perf
    iterations (EXPERIMENTS.md EPSM log): 64.7ms -> 2.8-3.6ms per MB.
    """
    t, p = _to_arrays(text, pattern)
    n, m = t.shape[0], p.shape[0]
    if m < 2 * beta:
        return epsmb(t, p)
    if n < m:
        return jnp.zeros((n,), dtype=jnp.bool_)

    weights = fingerprint_weights(beta)
    noff = m - beta + 1
    # --- preprocessing: fingerprints of pattern substrings -----------------
    offs = jnp.arange(noff)
    pat_blocks = p[offs[:, None] + jnp.arange(beta)[None, :]]  # (noff, beta)
    hp = hash_blocks(pat_blocks, weights, kbits)  # (noff,)

    # --- search: strided aligned block fingerprints ------------------------
    stride = _epsmc_stride(m, beta)
    nblk = max(0, (n - beta) // stride + 1)
    bstart = jnp.arange(nblk) * stride  # aligned inspected block starts
    # Inspected blocks via pad+reshape+slice: stride >= beta always (m >=
    # 2*beta), so block i is the first beta bytes of row i — a strided view,
    # NO gather (§Perf EPSM iteration 3: the 1M-element block gather was the
    # O(n) floor of the filter phase).
    t_pad = jnp.pad(t, (0, max(0, nblk * stride + beta - n)))
    blocks = t_pad[: nblk * stride].reshape(nblk, stride)[:, :beta]
    ht = hash_blocks(blocks, weights, kbits)  # (nblk,)

    # --- candidate generation: the paper's 2^k table L ----------------------
    # We first adapted L to a dense (blocks x offsets) compare ("the TPU
    # idiom"); measurement showed the compare + pair-compaction is the O(n)
    # floor on the vector backend, so we re-adopted the paper's own lookup
    # table at BLOCK granularity (§Perf EPSM iteration 3): one 2^k-bool LUT
    # probe per block, then offset-wise verification only at probed blocks.
    lut = jnp.zeros((1 << kbits,), jnp.bool_).at[hp].set(True)
    cand_blk = lut[ht]  # (nblk,) does this block hash-match ANY offset?

    # expected block hit-rate on random text is noff/2^k; budget 4x that
    # (or cand_frac, whichever is larger) keeps the sparse path hot while
    # the dense fallback still guarantees exactness on adversarial inputs
    frac = max(cand_frac, 4.0 * noff / (1 << kbits))
    budget = max(64, int(nblk * frac))
    budget = min(budget, nblk)
    n_cand = cand_blk.sum(dtype=jnp.int32)
    m_pad = m - beta
    span = m_pad + m  # candidate starts for a block cover [bstart-m_pad, bstart]

    def sparse_verify(_):
        (bidx,) = jnp.nonzero(cand_blk, size=budget, fill_value=-1)
        valid = bidx >= 0
        bsel = jnp.where(valid, bidx, 0) * stride  # block starts
        # contiguous span rows around each candidate block (front-padded)
        t_span = jnp.pad(t, (m_pad, span))
        rows = t_span[bsel[:, None] + jnp.arange(span)]  # (nb, span)
        oks, sts = [], []
        for j in range(noff):  # static slicing within rows; noff is small
            win = rows[:, m_pad - j : m_pad - j + m]  # window at start bsel-j
            st = bsel - j
            ok = (
                valid
                & (st >= 0)
                & (st <= n - m)
                & jnp.all(win == p[None, :], axis=-1)
            )
            oks.append(ok)
            sts.append(st)
        # one batched scatter (a scatter per offset dominated at large noff)
        ok_all = jnp.stack(oks).reshape(-1)
        st_all = jnp.stack(sts).reshape(-1)
        mask = jnp.zeros((n,), dtype=jnp.bool_)
        return mask.at[jnp.where(ok_all, st_all, n)].max(ok_all, mode="drop")

    def dense_verify(_):
        starts = bstart[:, None] - offs[None, :]  # (nblk, noff)
        cand = cand_blk[:, None] & (starts >= 0) & (starts <= n - m)
        safe = jnp.where(cand, starts, 0)
        windows = t[safe[..., None] + jnp.arange(m)]  # (nblk, noff, m)
        ok = jnp.all(windows == p[None, None, :], axis=-1) & cand
        flat_idx = jnp.where(ok, starts, n).reshape(-1)
        mask = jnp.zeros((n,), dtype=jnp.bool_)
        return mask.at[flat_idx].max(ok.reshape(-1), mode="drop")

    return lax.cond(n_cand <= budget, sparse_verify, dense_verify, operand=None)


# ---------------------------------------------------------------------------
# Dispatcher (paper Section 3: EPSMa m<4, EPSMb 4<=m<16, EPSMc m>=16)
# ---------------------------------------------------------------------------

_ALGOS = {
    "epsma": epsma,
    "epsmb": epsmb,
    "epsmc": epsmc,
}


def select_algo(m: int) -> str:
    """Paper-faithful regime thresholds (tuned for SSE in the paper)."""
    if m < EPSMA_MAX:
        return "epsma"
    if m < EPSMB_MAX:
        return "epsmb"
    return "epsmc"


# Backend-measured crossover (XLA-CPU, EXPERIMENTS.md §Perf EPSM log):
# before iteration 3 the fingerprint filter lost to the packed anchor until
# m ~ 128 on this backend; after re-adopting the paper's 2^k LUT + block
# compaction it wins from m = 16 — i.e. the PAPER's thresholds are optimal
# here too.  Kept as a named constant because it is a per-backend tuning
# surface (re-measure with benchmarks/paper_tables.py on new hardware).
TUNED_EPSMC_MIN = 16


def select_algo_tuned(m: int) -> str:
    if m < EPSMA_MAX:
        return "epsma"
    if m < TUNED_EPSMC_MIN:
        return "epsmb"
    return "epsmc"


# Auto-streaming threshold for host-side inputs (repro.core.stream,
# DESIGN.md §9): above this, find/count scan in O(chunk) device memory via
# the StreamScanner instead of materializing the ~9 bytes/byte resident
# index.  Device-resident inputs never auto-stream (they already fit).
STREAM_AUTO_BYTES = 1 << 26


def _host_bytes(text) -> int:
    """Length of a HOST-side text (0 for device arrays: never auto-stream)."""
    if isinstance(text, (bytes, bytearray, memoryview, str)):
        return len(text)
    import numpy as _np

    if isinstance(text, _np.ndarray):
        return text.size
    return 0


def find(text, pattern, *, algo: str = "auto", k: int = 0,
         stream: Optional[bool] = None) -> jnp.ndarray:
    """Match-start mask for all occurrences of pattern in text.

    ``k`` is a Hamming mismatch budget (repro.approx, DESIGN.md §8): k > 0
    reports every position whose m-byte window differs from the pattern in
    at most k bytes (``algo`` is ignored — the engine's packed counting
    filter replaces the regime dispatch).  k=0 is the exact paper path.

    ``stream`` is the bounded-memory escape hatch (repro.core.stream,
    DESIGN.md §9): True scans the text chunk-by-chunk in O(chunk) device
    memory and returns a HOST bool mask (``algo`` is ignored — the engine's
    regime dispatch runs per chunk); None auto-enables it for host-side
    texts >= STREAM_AUTO_BYTES, but ONLY under the default regime dispatch —
    an explicit ``algo`` request always runs resident as asked.  Results are
    identical to the resident scan.
    """
    if stream is None:
        stream = algo == "auto" and _host_bytes(text) >= STREAM_AUTO_BYTES
    if stream:
        from repro.core.stream import find_stream

        return find_stream(text, pattern, k=k)
    t, p = _to_arrays(text, pattern)
    m = p.shape[0]
    if m == 0:
        raise ValueError("empty pattern")
    if k:
        from repro.approx import find_kmismatch

        return find_kmismatch(t, p, k)
    if algo == "auto":
        name = select_algo(m)
    elif algo == "tuned":
        name = select_algo_tuned(m)
    else:
        name = algo
    if name not in _ALGOS:
        raise ValueError(
            f"unknown algo {name!r}; choose from {sorted(_ALGOS)} or auto/tuned"
        )
    return _ALGOS[name](t, p)


def count(text, pattern, *, algo: str = "auto", k: int = 0,
          stream: Optional[bool] = None) -> jnp.ndarray:
    """Occurrence count; ``stream`` as in :func:`find` — the streaming path
    never materializes a whole-text mask (device OR host)."""
    if stream is None:
        stream = algo == "auto" and _host_bytes(text) >= STREAM_AUTO_BYTES
    if stream:
        from repro.core.stream import stream_count

        return stream_count(text, [pattern], k=k)[0]
    return find(text, pattern, algo=algo, k=k, stream=False).sum(dtype=jnp.int32)


def positions(text, pattern, *, algo: str = "auto", k: int = 0,
              stream: Optional[bool] = None):
    """Occurrence start positions (host-side; forces a sync)."""
    import numpy as np

    mask = jax.device_get(find(text, pattern, algo=algo, k=k, stream=stream))
    return np.nonzero(mask)[0]


@functools.partial(jax.jit, static_argnames=("algo",))
def find_jit(text: jnp.ndarray, pattern: jnp.ndarray, *, algo: str = "auto") -> jnp.ndarray:
    return find(text, pattern, algo=algo)


@functools.partial(jax.jit, static_argnames=("algo",))
def count_jit(text: jnp.ndarray, pattern: jnp.ndarray, *, algo: str = "auto") -> jnp.ndarray:
    return count(text, pattern, algo=algo)
