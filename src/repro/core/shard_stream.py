"""Multi-host sharded streaming scans (DESIGN.md §10).

The packed filter is embarrassingly parallel over text blocks: disjoint
segments of one logical stream can be scanned independently as long as each
carries an m-1 overlap across its left boundary (Belazzougui's word-RAM
block-split argument, PAPERS.md).  PR 4's :class:`~repro.core.stream.
StreamScanner` already enforces exactly that seam rule between chunks of ONE
scan; this module applies it a second time, between SCANS:

  * the stream is range-partitioned into per-shard byte ranges
    ``[s_i, s_{i+1})`` with beta-aligned boundaries
    (:func:`repro.dist.sharding.range_partition`), so every shard's windows
    keep the global EPSMc block phase;

  * shard i runs the ordinary StreamScanner chunk loop over its range with
    the ``roundup(max_m - 1, beta)`` overlap prefix — the bytes immediately
    before ``s_i`` — injected into its first window
    (``count_many(..., prefix=, start=)``), and end-position attribution
    makes it own exactly the occurrences whose last byte falls inside its
    range: no misses, no double counts, for ANY shard count, including
    shards narrower than ``max_m - 1`` and empty shards;

  * results merge through ``repro.dist`` collectives: counts are summed
    device-side (``compat.sum_across_devices`` — one cross-device reduce
    over the shard axis) then psum'd across jax.distributed processes;
    positions are already global (each shard's masks carry its byte
    offset), so the merge is an offset-shifted concat gather — shard start
    ranges are disjoint per pattern, so shard-order concatenation is
    already sorted;

  * a shard whose HOST loop fails (source error, short/truncated range
    read, injected fault) is retried ``max_retries`` times by re-opening
    its byte range and rescanning from scratch (``dist.fault_tolerance.
    run_with_retries``); partial attempts are discarded, so a retried
    shard's contribution is bit-identical to a clean pass.  Device-side
    failures surface at the collective merge, NOT inside the retry scope —
    the per-shard accumulators are deliberately never synced mid-scan
    (syncing per shard would serialize the fleet), so a lost device raises
    to the caller: loud, never an undercount.

Within one process, shards round-robin over the local devices and each
device's async dispatch queue drains concurrently (the host loop for shard
i+1 overlaps the device compute of shard i); across processes, each process
scans the shards ``i % process_count == process_index`` and merges through
the multihost collectives.  Single host, single device, the sharded scan
degenerates to the plain StreamScanner and is bit-identical to it.
"""

from __future__ import annotations

import os
import threading
import weakref
from typing import Iterator, List, Optional, Sequence

import numpy as np

import jax

from repro.core import engine
from repro.core.engine import PatternPlan
from repro.core.epsm import EPSMC_BETA
from repro.core.stream import (
    Compressed,
    StreamScanner,
    _as_chunks,
)
from repro.dist import compat
from repro.dist.fault_tolerance import ShardRetry, run_with_retries
from repro.dist.sharding import StreamShardSpec, make_stream_shard_spec

# file-like sources share one OS handle between shards: reads go through a
# per-handle lock so concurrently-scanned shards can't interleave seek/read
_FILE_LOCKS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _normalize_source(source):
    if isinstance(source, str):
        return source.encode("utf-8", errors="surrogateescape")
    if isinstance(source, jax.Array):
        return np.asarray(jax.device_get(source)).astype(np.uint8).reshape(-1)
    if isinstance(source, np.ndarray):
        a = source.reshape(-1)
        return a if a.dtype == np.uint8 else a.astype(np.uint8)
    return source


def _is_sliceable(source) -> bool:
    return isinstance(source, (bytes, bytearray, memoryview, np.ndarray))


def source_total_bytes(source, total_bytes: Optional[int] = None) -> int:
    """Logical length of a range-partitionable source.

    Sliceable buffers and seekable files know their own length; callable
    range sources need ``total_bytes`` (or a ``total_bytes`` attribute).
    Compressed and one-shot-iterable sources cannot be range-partitioned —
    there is no random access to hand each shard its own range."""
    if total_bytes is not None:
        return int(total_bytes)
    source = _normalize_source(source)
    if _is_sliceable(source):
        return len(source)
    if isinstance(source, os.PathLike):
        return os.stat(os.fspath(source)).st_size
    if hasattr(source, "seek") and hasattr(source, "read"):
        pos = source.tell()
        size = source.seek(0, os.SEEK_END)
        source.seek(pos)
        return int(size)
    got = getattr(source, "total_bytes", None)
    if got is not None:
        return int(got)
    if isinstance(source, Compressed):
        raise TypeError(
            "Compressed sources cannot be range-partitioned (no random "
            "access); decompress to a file/buffer first, or stream it "
            "unsharded through StreamScanner"
        )
    raise TypeError(
        f"cannot determine the length of {type(source).__name__} source; "
        "pass total_bytes= (and a callable open_range-style source)"
    )


def _file_pread_chunks(f, start: int, stop: int, lock) -> Iterator[np.ndarray]:
    pos = start
    while pos < stop:
        n = min(1 << 20, stop - pos)
        with lock:
            f.seek(pos)
            b = f.read(n)
        if not b:
            return  # short file: treat like an exhausted stream
        pos += len(b)
        yield np.frombuffer(bytes(b), np.uint8)


def open_range(source, start: int, stop: int):
    """A chunk source for bytes [start, stop) of the logical stream —
    re-openable, so a failed shard can be rescanned from scratch.

    Accepts sliceable buffers (zero-copy views), ``os.PathLike`` (a fresh
    handle per range: shards on different devices read in parallel),
    seekable file-likes (shared handle, per-handle read lock), and callables
    ``source(start, stop) -> chunk source`` for object stores / remote
    corpora."""
    source = _normalize_source(source)
    start, stop = int(start), int(stop)
    if stop < start:
        raise ValueError(f"bad range [{start}, {stop})")
    if _is_sliceable(source):
        return source[start:stop]
    if isinstance(source, os.PathLike):

        def gen():
            with open(os.fspath(source), "rb") as f:
                yield from _file_pread_chunks(f, start, stop, threading.Lock())

        return gen()
    if hasattr(source, "seek") and hasattr(source, "read"):
        lock = _FILE_LOCKS.setdefault(source, threading.Lock())
        return _file_pread_chunks(source, start, stop, lock)
    if callable(source):
        return source(start, stop)
    raise TypeError(
        f"{type(source).__name__} source supports no random access; "
        "sharded scans need a sliceable buffer, path, seekable file, or "
        "callable (start, stop) -> chunks"
    )


def read_range(source, start: int, stop: int) -> np.ndarray:
    """Materialize bytes [start, stop) on the host (overlap prefixes only —
    at most ``overlap`` bytes, never a shard body)."""
    pieces, need = [], stop - start
    for c in _as_chunks(open_range(source, start, stop)):
        pieces.append(c[:need])
        need -= len(pieces[-1])
        if need <= 0:
            break
    if not pieces:
        return np.zeros(0, np.uint8)
    return np.concatenate(pieces)


class ShortRangeRead(IOError):
    """A shard's source delivered the wrong number of bytes for its range
    (truncated file, misbehaving range callable).  Raised INSIDE the retry
    scope, so a transient short read is rescanned and a persistent one
    propagates — never a silent undercount."""


def _exact_chunks(range_source, need: int, shard: int) -> Iterator[np.ndarray]:
    got = 0
    for c in _as_chunks(range_source):
        got += len(c)
        yield c
    if got != need:
        raise ShortRangeRead(
            f"shard {shard}: range source delivered {got} bytes, "
            f"expected {need}"
        )


class ShardedStreamScanner:
    """Range-partitioned streaming matcher: S shards, one seam rule, exact.

    ``n_shards`` defaults to the global device count (local devices x
    processes).  Within a process, shards round-robin over ``devices``
    (default: all local devices) with per-device plan replicas
    (``engine.replicate_plans``) compiled once and reused by every shard on
    that device; each shard's dispatches enqueue on its own device, so the
    scans drain concurrently.  Across jax.distributed processes, each
    process owns the shards ``i % process_count == process_index``.

    Results are bit-identical to a single-host :class:`StreamScanner` for
    every shard count — the acceptance property the CI ``multihost`` job
    sweeps under 8 forced host devices.
    """

    def __init__(
        self,
        plans: Sequence[PatternPlan],
        n_shards: Optional[int] = None,
        chunk_bytes="auto",
        *,
        k: Optional[int] = None,
        devices=None,
        max_retries: int = 1,
        fused: bool = True,
        use_kernel: bool = False,
    ):
        self.plans = tuple(plans)
        template = StreamScanner(
            self.plans, chunk_bytes, k=k, fused=fused, use_kernel=use_kernel
        )
        self.overlap = template.overlap
        self.max_m = template.max_m
        self.n_patterns = template.n_patterns
        self.order = template.order
        # the template resolves "auto" once; every shard reuses the int
        self.chunk_bytes = template.chunk_bytes
        self.k = k
        self.fused = fused
        self.use_kernel = use_kernel
        if devices is None:
            local = jax.local_devices()
            devices = local if len(local) > 1 else [None]
        self.devices = list(devices)
        self.n_shards = int(n_shards) if n_shards else max(1, jax.device_count())
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.max_retries = int(max_retries)
        self.events: List[ShardRetry] = []
        self.dispatch_count = 0
        self._replicas: dict = {}

    # -- shard plumbing -----------------------------------------------------

    def shard_spec(self, total_bytes: int) -> StreamShardSpec:
        return make_stream_shard_spec(
            total_bytes, self.n_shards, overlap=self.overlap, align=EPSMC_BETA
        )

    def _plans_on(self, device):
        if device is None:
            return self.plans
        got = self._replicas.get(device)
        if got is None:
            got = self._replicas[device] = engine.replicate_plans(
                self.plans, device
            )
        return got

    def _scanner(self, shard_i: int) -> StreamScanner:
        device = self.devices[shard_i % len(self.devices)]
        return StreamScanner(
            self._plans_on(device), self.chunk_bytes, k=self.k, device=device,
            fused=self.fused, use_kernel=self.use_kernel,
        )

    def _my_shards(self, n_shards: int) -> range:
        return range(jax.process_index(), n_shards, jax.process_count())

    def _scan_shard(self, source, spec: StreamShardSpec, i: int, consume):
        """Run ``consume(scanner, range_source, prefix, start)`` for shard i
        with re-open-and-rescan retry; returns consume's result."""
        s, e = spec.ranges[i]

        def attempt():
            prefix = None
            if s > 0:
                ps, pe = spec.prefix_range(i)
                prefix = read_range(source, ps, pe)
                if len(prefix) != pe - ps:
                    raise ShortRangeRead(
                        f"shard {i}: overlap prefix delivered "
                        f"{len(prefix)} bytes, expected {pe - ps}"
                    )
            sc = self._scanner(i)
            rs = _exact_chunks(open_range(source, s, e), e - s, i)
            out = consume(sc, rs, prefix, s)
            return sc, out

        def on_failure(attempt_i, exc):
            self.events.append(
                ShardRetry(shard=i, attempt=attempt_i, error=repr(exc))
            )

        sc, out = run_with_retries(
            attempt, retries=self.max_retries, on_failure=on_failure
        )
        self.dispatch_count += sc.dispatch_count
        return out

    # -- queries ------------------------------------------------------------

    def count_many(self, source, *, total_bytes: Optional[int] = None) -> np.ndarray:
        """int32 (P_total,) exact occurrence counts over the whole logical
        stream: per-shard device accumulators, one cross-device reduce, one
        cross-process psum.  Nothing syncs until the merge, so every local
        shard's chunks are in flight together."""
        source = _normalize_source(source)
        spec = self.shard_spec(source_total_bytes(source, total_bytes))
        parts = [
            self._scan_shard(
                source, spec, i,
                lambda sc, rs, pre, st: sc.count_device(rs, prefix=pre, start=st),
            )
            for i in self._my_shards(spec.n_shards)
        ]
        if parts:
            local = compat.sum_across_devices(parts)
        else:  # more processes than shards: contribute zeros to the psum
            local = np.zeros((self.n_patterns,), np.int32)
        return compat.process_allsum(local).astype(np.int32)

    def any_many(self, source, *, total_bytes: Optional[int] = None) -> np.ndarray:
        """bool (P_total,) — does each pattern occur anywhere in the stream?"""
        return self.count_many(source, total_bytes=total_bytes) > 0

    def positions_many(
        self, source, *, total_bytes: Optional[int] = None
    ) -> List[np.ndarray]:
        """Per-pattern sorted global occurrence start positions.

        Each shard's masks already carry global bases, so the merge is a
        concat in shard order — start ranges are disjoint across shards (an
        occurrence belongs to the shard holding its END byte, and ends are
        partitioned), hence the result is sorted without a global sort.
        Across processes, rows are exchanged via the ragged all-gather."""
        source = _normalize_source(source)
        spec = self.shard_spec(source_total_bytes(source, total_bytes))
        rows: List[List[np.ndarray]] = [[] for _ in range(self.n_patterns)]

        def consume(sc, rs, pre, st):
            return sc.positions_many(rs, prefix=pre, start=st)

        for i in self._my_shards(spec.n_shards):
            got = self._scan_shard(source, spec, i, consume)
            for p_i in range(self.n_patterns):
                rows[p_i].append(got[p_i])
        local = [
            np.concatenate(r) if r else np.zeros(0, np.int64) for r in rows
        ]
        if jax.process_count() == 1:
            return local
        return [
            np.sort(np.concatenate(compat.process_allgather_ragged(row)))
            for row in local
        ]


def shard_stream_count(
    source,
    patterns: Sequence,
    *,
    n_shards: Optional[int] = None,
    k: int = 0,
    chunk_bytes="auto",
    total_bytes: Optional[int] = None,
) -> np.ndarray:
    """int32 (P,) exact (or <= k-mismatch) sharded counts in ORIGINAL
    pattern order — the sharded sibling of :func:`stream.stream_count`."""
    plans = engine.compile_patterns_cached(list(patterns), k=k)
    sc = ShardedStreamScanner(plans, n_shards, chunk_bytes, k=k)
    counts = sc.count_many(source, total_bytes=total_bytes)
    out = np.zeros_like(counts)
    out[sc.order] = counts
    return out
