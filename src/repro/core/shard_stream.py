"""Multi-host sharded streaming scans (DESIGN.md §10).

The packed filter is embarrassingly parallel over text blocks: disjoint
segments of one logical stream can be scanned independently as long as each
carries an m-1 overlap across its left boundary (Belazzougui's word-RAM
block-split argument, PAPERS.md).  PR 4's :class:`~repro.core.stream.
StreamScanner` already enforces exactly that seam rule between chunks of ONE
scan; this module applies it a second time, between SCANS:

  * the stream is range-partitioned into per-shard byte ranges
    ``[s_i, s_{i+1})`` with beta-aligned boundaries
    (:func:`repro.dist.sharding.range_partition`), so every shard's windows
    keep the global EPSMc block phase;

  * shard i runs the ordinary StreamScanner chunk loop over its range with
    the ``roundup(max_m - 1, beta)`` overlap prefix — the bytes immediately
    before ``s_i`` — injected into its first window
    (``count_many(..., prefix=, start=)``), and end-position attribution
    makes it own exactly the occurrences whose last byte falls inside its
    range: no misses, no double counts, for ANY shard count, including
    shards narrower than ``max_m - 1`` and empty shards;

  * results merge through ``repro.dist`` collectives: counts are summed
    device-side (``compat.sum_across_devices`` — one cross-device reduce
    over the shard axis) then psum'd across jax.distributed processes;
    positions are already global (each shard's masks carry its byte
    offset), so the merge is an offset-shifted concat gather — shard start
    ranges are disjoint per pattern, so shard-order concatenation is
    already sorted;

  * a shard whose HOST loop fails (source error, short/truncated range
    read, injected fault) is retried ``max_retries`` times by re-opening
    its byte range and rescanning from scratch (``dist.fault_tolerance.
    run_with_retries``); partial attempts are discarded, so a retried
    shard's contribution is bit-identical to a clean pass.  Device-side
    failures surface at the collective merge, NOT inside the retry scope —
    the per-shard accumulators are deliberately never synced mid-scan
    (syncing per shard would serialize the fleet), so a lost device raises
    to the caller: loud, never an undercount.

Within one process, shards round-robin over the local devices and each
device's async dispatch queue drains concurrently (the host loop for shard
i+1 overlaps the device compute of shard i); across processes, each process
scans the shards ``i % process_count == process_index`` and merges through
the multihost collectives.  Single host, single device, the sharded scan
degenerates to the plain StreamScanner and is bit-identical to it.

The ELASTIC layer (DESIGN.md §12) rides on the same seam rule:

  * ``steal=True`` runs this process's shards on a small thread-lane pool
    over a shared work deque.  A per-scan :class:`~repro.dist.
    fault_tolerance.StepWatchdog` flags a straggling shard, which SHEDS its
    trailing beta-aligned byte range back onto the deque; an idle lane also
    steals the trailing half of the busiest in-flight scan.  Because any
    beta-aligned partition with overlap prefixes merges exactly (end-
    position attribution — the PR 5 seam argument), a stolen range's
    contribution is bit-identical to the victim having finished it: steals
    repartition the stream, they never change the answer.

  * ``on_exhausted="partial"`` degrades gracefully: a shard that exhausts
    its retry budget is RECORDED, not raised, and the query returns a
    :class:`PartialScanResult` whose counts/positions cover exactly the
    merged byte ranges that were scanned, with the missing ranges explicit.

  * ``fault_plan=`` threads a :class:`~repro.dist.fault_injection.FaultPlan`
    through the per-shard attempt scope (site kind ``"shard"``), so chaos
    tests crash whole shards inside the same retry machinery real failures
    exercise.
"""

from __future__ import annotations

import dataclasses
import logging
import os
import threading
import weakref
from collections import deque
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.core import engine
from repro.core.engine import PatternPlan
from repro.core.epsm import EPSMC_BETA
from repro.core.stream import (
    Compressed,
    StreamScanner,
    _as_chunks,
    _round_up,
)
from repro.dist import compat
from repro.dist.fault_tolerance import (
    BackoffPolicy,
    ShardRetry,
    StepWatchdog,
    run_with_retries,
)
from repro.dist.sharding import (
    StreamShardSpec,
    complement_ranges,
    make_stream_shard_spec,
    merge_ranges,
)
from repro.obs.recorder import Recorder, logging_sink

_LOG = logging.getLogger("repro.shard_stream")

# Default flight recorder: disabled (no spans/fencing — the static and
# elastic paths keep their pipeline shape) but with the module logger as an
# event sink, so steal/shed/retry/straggler events surface as log lines when
# no recorder is attached (DESIGN.md §13).
_DEFAULT_REC = Recorder(enabled=False, fence=False, sinks=(logging_sink(_LOG),))

# file-like sources share one OS handle between shards: reads go through a
# per-handle lock so concurrently-scanned shards can't interleave seek/read
_FILE_LOCKS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _normalize_source(source):
    if isinstance(source, str):
        return source.encode("utf-8", errors="surrogateescape")
    if isinstance(source, jax.Array):
        return np.asarray(jax.device_get(source)).astype(np.uint8).reshape(-1)
    if isinstance(source, np.ndarray):
        a = source.reshape(-1)
        return a if a.dtype == np.uint8 else a.astype(np.uint8)
    return source


def _is_sliceable(source) -> bool:
    return isinstance(source, (bytes, bytearray, memoryview, np.ndarray))


def source_total_bytes(source, total_bytes: Optional[int] = None) -> int:
    """Logical length of a range-partitionable source.

    Sliceable buffers and seekable files know their own length; callable
    range sources need ``total_bytes`` (or a ``total_bytes`` attribute).
    Compressed and one-shot-iterable sources cannot be range-partitioned —
    there is no random access to hand each shard its own range."""
    if total_bytes is not None:
        return int(total_bytes)
    source = _normalize_source(source)
    if _is_sliceable(source):
        return len(source)
    if isinstance(source, os.PathLike):
        return os.stat(os.fspath(source)).st_size
    if hasattr(source, "seek") and hasattr(source, "read"):
        pos = source.tell()
        size = source.seek(0, os.SEEK_END)
        source.seek(pos)
        return int(size)
    got = getattr(source, "total_bytes", None)
    if got is not None:
        return int(got)
    if isinstance(source, Compressed):
        raise TypeError(
            "Compressed sources cannot be range-partitioned (no random "
            "access); decompress to a file/buffer first, or stream it "
            "unsharded through StreamScanner"
        )
    raise TypeError(
        f"cannot determine the length of {type(source).__name__} source; "
        "pass total_bytes= (and a callable open_range-style source)"
    )


def _file_pread_chunks(f, start: int, stop: int, lock) -> Iterator[np.ndarray]:
    pos = start
    while pos < stop:
        n = min(1 << 20, stop - pos)
        with lock:
            f.seek(pos)
            b = f.read(n)
        if not b:
            return  # short file: treat like an exhausted stream
        pos += len(b)
        yield np.frombuffer(bytes(b), np.uint8)


def open_range(source, start: int, stop: int):
    """A chunk source for bytes [start, stop) of the logical stream —
    re-openable, so a failed shard can be rescanned from scratch.

    Accepts sliceable buffers (zero-copy views), ``os.PathLike`` (a fresh
    handle per range: shards on different devices read in parallel),
    seekable file-likes (shared handle, per-handle read lock), and callables
    ``source(start, stop) -> chunk source`` for object stores / remote
    corpora."""
    source = _normalize_source(source)
    start, stop = int(start), int(stop)
    if stop < start:
        raise ValueError(f"bad range [{start}, {stop})")
    if _is_sliceable(source):
        return source[start:stop]
    if isinstance(source, os.PathLike):

        def gen():
            with open(os.fspath(source), "rb") as f:
                yield from _file_pread_chunks(f, start, stop, threading.Lock())

        return gen()
    if hasattr(source, "seek") and hasattr(source, "read"):
        lock = _FILE_LOCKS.setdefault(source, threading.Lock())
        return _file_pread_chunks(source, start, stop, lock)
    if callable(source):
        return source(start, stop)
    raise TypeError(
        f"{type(source).__name__} source supports no random access; "
        "sharded scans need a sliceable buffer, path, seekable file, or "
        "callable (start, stop) -> chunks"
    )


def read_range(source, start: int, stop: int) -> np.ndarray:
    """Materialize bytes [start, stop) on the host (overlap prefixes only —
    at most ``overlap`` bytes, never a shard body)."""
    pieces, need = [], stop - start
    for c in _as_chunks(open_range(source, start, stop)):
        pieces.append(c[:need])
        need -= len(pieces[-1])
        if need <= 0:
            break
    if not pieces:
        return np.zeros(0, np.uint8)
    return np.concatenate(pieces)


class ShortRangeRead(IOError):
    """A shard's source delivered the wrong number of bytes for its range
    (truncated file, misbehaving range callable).  Raised INSIDE the retry
    scope, so a transient short read is rescanned and a persistent one
    propagates — never a silent undercount."""


def _exact_chunks(range_source, need: int, shard: int) -> Iterator[np.ndarray]:
    got = 0
    for c in _as_chunks(range_source):
        got += len(c)
        yield c
    if got != need:
        raise ShortRangeRead(
            f"shard {shard}: range source delivered {got} bytes, "
            f"expected {need}"
        )


@dataclasses.dataclass
class StealEvent:
    """One beta-aligned trailing range moved off an in-flight scan.

    ``thief`` is the stealing lane for an idle-initiated steal, or ``None``
    for a watchdog shed (the range went to the shared deque for whichever
    lane frees up first).  ``victim`` is the ORIGIN shard id of the split
    work item — steals of stolen ranges keep the original id, so the event
    log traces every byte back to its shard."""

    victim: int
    thief: Optional[int]
    start: int
    stop: int
    reason: str  # "idle" | "straggler"


@dataclasses.dataclass
class PartialScanResult:
    """A scan that covered only part of the stream (``on_exhausted=
    "partial"``): counts/positions are exact over ``covered`` — an
    occurrence is included iff its END byte lies in a covered range — and
    ``missing`` lists the byte ranges lost to exhausted retries.  Both are
    merged, sorted, disjoint, and together tile ``[0, total_bytes)``.  A
    fully covered scan still returns this type (``complete`` is True), so
    callers opting into degradation get a stable shape."""

    total_bytes: int
    covered: Tuple[Tuple[int, int], ...]
    missing: Tuple[Tuple[int, int], ...]
    counts: Optional[np.ndarray] = None
    positions: Optional[List[np.ndarray]] = None

    @property
    def complete(self) -> bool:
        return not self.missing

    @property
    def covered_bytes(self) -> int:
        return sum(e - s for s, e in self.covered)

    def coverage_fraction(self) -> float:
        if self.total_bytes == 0:
            return 1.0
        return self.covered_bytes / self.total_bytes


class _WorkItem:
    """One schedulable byte range.  ``stop`` is mutable: sheds trim it, and
    the trimmed value is what a retry rescans / an exhausted item reports
    missing — a shed range is owned by its new item, never double-counted."""

    __slots__ = ("start", "stop", "origin")

    def __init__(self, start: int, stop: int, origin: int):
        self.start = int(start)
        self.stop = int(stop)
        self.origin = int(origin)


class _StealableScan:
    """An in-flight range scan whose trailing bytes can be stolen.

    The piece generator reserves bytes under the lock BEFORE yielding them
    (``pos`` is the commit point), and :meth:`try_shed` only ever splits at
    a beta-aligned point strictly past ``pos`` — so a steal can never take
    back bytes the scanner already consumed, and the victim's scan simply
    ends early at the new ``stop``.  Both sides of the split keep the global
    EPSMc block phase (the split point is beta-aligned) and the thief
    injects the standard overlap prefix, so the merged result is
    bit-identical to the unsplit scan (DESIGN.md §12)."""

    def __init__(self, source, start: int, stop: int, *, align: int, piece_bytes: int):
        self.source = source
        self.start = int(start)
        self.pos = int(start)        # bytes committed to the scanner
        self.stop = int(stop)        # mutable: sheds trim it
        self.align = int(align)
        self.piece_bytes = max(1, int(piece_bytes))
        self.retired = False  # set when the attempt ends; refuses late sheds
        self.lock = threading.Lock()

    def remaining(self) -> int:
        with self.lock:
            return self.stop - self.pos

    def retire(self) -> int:
        """End of attempt: freeze ``stop`` against further sheds and return
        it.  Atomic with try_shed, so a shed either lands before the frozen
        stop is recorded (the retry excludes it) or is refused — a stolen
        range is never also rescanned by its victim."""
        with self.lock:
            self.retired = True
            return self.stop

    def try_shed(self, min_shed: int) -> Optional[Tuple[int, int]]:
        """Split off the trailing ~half of the unscanned range at a beta-
        aligned point; returns the shed (start, stop) or None if what's
        left is too small to be worth a second overlap-prefix read."""
        with self.lock:
            if self.retired:
                return None
            lo = _round_up(self.pos, self.align)
            mid = self.pos + (self.stop - self.pos) // 2
            split = max(lo, _round_up(mid, self.align))
            if split >= self.stop or self.stop - split < min_shed:
                return None
            shed = (split, self.stop)
            self.stop = split
            return shed

    def chunks(self) -> Iterator[np.ndarray]:
        """Reserve-then-yield piece stream over [start, stop), audited:
        under-delivery raises ShortRangeRead inside the retry scope.  The
        underlying range is opened at the CURRENT stop; a later shed just
        stops consumption early at the trimmed stop."""
        opened_stop = self.stop
        it = _as_chunks(open_range(self.source, self.start, opened_stop))
        for piece in it:
            off = 0
            while off < len(piece):
                with self.lock:
                    if self.pos >= self.stop:
                        return  # trailing bytes were shed
                    take = min(
                        self.piece_bytes, len(piece) - off, self.stop - self.pos
                    )
                    self.pos += take
                yield piece[off : off + take]
                off += take
        with self.lock:
            if self.pos < self.stop:
                raise ShortRangeRead(
                    f"range [{self.start}, {self.stop}): source delivered "
                    f"{self.pos - self.start} bytes, "
                    f"expected {self.stop - self.start}"
                )


class ShardedStreamScanner:
    """Range-partitioned streaming matcher: S shards, one seam rule, exact.

    ``n_shards`` defaults to the global device count (local devices x
    processes).  Within a process, shards round-robin over ``devices``
    (default: all local devices) with per-device plan replicas
    (``engine.replicate_plans``) compiled once and reused by every shard on
    that device; each shard's dispatches enqueue on its own device, so the
    scans drain concurrently.  Across jax.distributed processes, each
    process owns the shards ``i % process_count == process_index``.

    Results are bit-identical to a single-host :class:`StreamScanner` for
    every shard count — the acceptance property the CI ``multihost`` job
    sweeps under 8 forced host devices.

    ``recorder`` (DESIGN.md §13) threads one flight recorder through every
    layer of a scan: per-shard/per-lane ``scan_range`` spans wrapping the
    chunk loop's ``host_prep``/``device_put``/``dispatch`` spans, ``steal``
    / ``shed`` / ``straggler`` / ``range_done`` / ``range_lost`` instant
    events whose beta-aligned byte ranges exactly tile the input, and the
    retry loop's ``retry``/``retry_exhausted`` events.  A ``fault_plan``
    without its own recorder inherits this one, so a chaos trace shows each
    injected fault next to the retry it triggered.
    """

    def __init__(
        self,
        plans: Sequence[PatternPlan],
        n_shards: Optional[int] = None,
        chunk_bytes="auto",
        *,
        k: Optional[int] = None,
        devices=None,
        max_retries: int = 1,
        fused: bool = True,
        use_kernel: bool = False,
        steal: bool = False,
        steal_workers: Optional[int] = None,
        min_steal_bytes: Optional[int] = None,
        straggler_factor: float = 3.0,
        on_exhausted: str = "raise",
        is_retryable=None,
        backoff: Optional[BackoffPolicy] = None,
        fault_plan=None,
        recorder: Optional[Recorder] = None,
    ):
        if on_exhausted not in ("raise", "partial"):
            raise ValueError(
                f"on_exhausted must be 'raise' or 'partial', got {on_exhausted!r}"
            )
        # one recorder serves every per-shard scanner, the retry loops, and
        # (when the caller didn't wire one) the fault plan, so a single
        # trace shows each injection next to the retry it triggered
        self.rec = _DEFAULT_REC if recorder is None else recorder
        if (
            recorder is not None
            and fault_plan is not None
            and getattr(fault_plan, "recorder", None) is None
        ):
            fault_plan.recorder = self.rec
        self.plans = tuple(plans)
        template = StreamScanner(
            self.plans, chunk_bytes, k=k, fused=fused, use_kernel=use_kernel,
            recorder=recorder,
        )
        self.overlap = template.overlap
        self.max_m = template.max_m
        self.n_patterns = template.n_patterns
        self.order = template.order
        # the template resolves "auto" once; every shard reuses the int
        self.chunk_bytes = template.chunk_bytes
        self.k = k
        self.fused = fused
        self.use_kernel = use_kernel
        if devices is None:
            local = jax.local_devices()
            devices = local if len(local) > 1 else [None]
        self.devices = list(devices)
        self.n_shards = int(n_shards) if n_shards else max(1, jax.device_count())
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self.max_retries = int(max_retries)
        self.steal = bool(steal)
        self.steal_workers = steal_workers
        self.min_steal_bytes = (
            max(self.chunk_bytes, 2 * self.overlap)
            if min_steal_bytes is None
            else int(min_steal_bytes)
        )
        self.straggler_factor = float(straggler_factor)
        self.on_exhausted = on_exhausted
        self.is_retryable = is_retryable
        self.backoff = backoff
        self.fault_plan = fault_plan
        self.events: List[ShardRetry] = []
        self.steal_events: List[StealEvent] = []
        self.dispatch_count = 0
        self._replicas: dict = {}
        self._lock = threading.Lock()

    # -- shard plumbing -----------------------------------------------------

    def shard_spec(self, total_bytes: int) -> StreamShardSpec:
        return make_stream_shard_spec(
            total_bytes, self.n_shards, overlap=self.overlap, align=EPSMC_BETA
        )

    def _plans_on(self, device):
        if device is None:
            return self.plans
        got = self._replicas.get(device)
        if got is None:
            got = self._replicas[device] = engine.replicate_plans(
                self.plans, device
            )
        return got

    def _scanner_on(self, device, lane: Optional[str] = None) -> StreamScanner:
        return StreamScanner(
            self._plans_on(device), self.chunk_bytes, k=self.k, device=device,
            fused=self.fused, use_kernel=self.use_kernel,
            recorder=self.rec, lane=lane,
        )

    def _scanner(self, shard_i: int) -> StreamScanner:
        return self._scanner_on(
            self.devices[shard_i % len(self.devices)], lane=f"shard{shard_i}"
        )

    def _my_shards(self, n_shards: int) -> range:
        return range(jax.process_index(), n_shards, jax.process_count())

    def _scan_shard(self, source, spec: StreamShardSpec, i: int, consume):
        """Run ``consume(scanner, range_source, prefix, start)`` for shard i
        with re-open-and-rescan retry; returns consume's result."""
        s, e = spec.ranges[i]
        lane = f"shard{i}"

        def attempt():
            with self.rec.span(
                "scan_range", lane=lane, shard=i, start=s, stop=e
            ):
                if self.fault_plan is not None:
                    self.fault_plan.check("shard", i)
                prefix = None
                if s > 0:
                    ps, pe = spec.prefix_range(i)
                    prefix = read_range(source, ps, pe)
                    if len(prefix) != pe - ps:
                        raise ShortRangeRead(
                            f"shard {i}: overlap prefix delivered "
                            f"{len(prefix)} bytes, expected {pe - ps}"
                        )
                sc = self._scanner(i)
                rs = _exact_chunks(open_range(source, s, e), e - s, i)
                out = consume(sc, rs, prefix, s)
            return sc, out

        def on_failure(attempt_i, exc):
            self.events.append(
                ShardRetry(shard=i, attempt=attempt_i, error=repr(exc))
            )

        sc, out = run_with_retries(
            attempt, retries=self.max_retries, on_failure=on_failure,
            is_retryable=self.is_retryable, backoff=self.backoff,
            recorder=self.rec, label=lane,
        )
        self.rec.event("range_done", lane=lane, origin=i, start=s, stop=e)
        self.dispatch_count += sc.dispatch_count
        return out

    # -- the elastic work-stealing path (DESIGN.md §12) ---------------------

    def _elastic_run(self, source, spec: StreamShardSpec, consume):
        """Scan this process's shard ranges on a thread-lane pool with work
        stealing; returns ``(results, missing)``.

        Stealing stays WITHIN a process (a stolen range would otherwise
        need a cross-process result channel; the inter-process partition is
        static).  Every lane pins a device; a lane's scans enqueue on that
        device, so lanes drain concurrently exactly like the round-robin
        static path.  ``results`` is an unordered list of per-item consume
        outputs, ``missing`` the byte ranges whose retries exhausted
        (``on_exhausted="partial"``; in raise mode the first error re-raises
        after the pool drains)."""
        lock = threading.Lock()
        work: deque = deque(
            _WorkItem(s, e, i)
            for i in self._my_shards(spec.n_shards)
            for (s, e) in (spec.ranges[i],)
            if e > s
        )
        results: list = []
        missing: List[Tuple[int, int]] = []
        errors: list = []
        active: dict = {}  # lane -> (_StealableScan, _WorkItem)
        n_lanes = (
            int(self.steal_workers)
            if self.steal_workers
            else max(2, len(self.devices))
        )
        n_lanes = max(1, min(n_lanes, len(work))) if work else 0
        lane_devices = [self.devices[j % len(self.devices)] for j in range(n_lanes)]
        for d in set(lane_devices):
            self._plans_on(d)  # replicate before threads touch the cache

        def push_shed(item: _WorkItem, shed, thief, reason):
            with lock:
                self.steal_events.append(
                    StealEvent(item.origin, thief, shed[0], shed[1], reason)
                )
                if thief is None:
                    work.append(_WorkItem(shed[0], shed[1], item.origin))
            self.rec.event(
                "steal" if thief is not None else "shed",
                victim=item.origin, thief=thief,
                start=shed[0], stop=shed[1], reason=reason,
            )

        def timed_chunks(scan: _StealableScan, item: _WorkItem, lane_name: str):
            # host-step watchdog: a straggling step sheds the trailing range
            wd = StepWatchdog(
                factor=self.straggler_factor, policy="log", min_history=3
            )
            it = scan.chunks()
            step = 0
            while True:
                wd.start_step(step)
                try:
                    piece = next(it)
                except StopIteration:
                    wd.end_step()
                    return
                if wd.end_step() is not None:
                    ev = wd.events[-1]
                    self.rec.event(
                        "straggler", lane=lane_name, origin=item.origin,
                        step=ev.step, duration_s=round(ev.duration_s, 6),
                        median_s=round(ev.median_s, 6),
                        factor=round(ev.factor, 2),
                    )
                    shed = scan.try_shed(self.min_steal_bytes)
                    if shed is not None:
                        push_shed(item, shed, None, "straggler")
                yield piece
                step += 1

        def scan_one(lane: int, device, item: _WorkItem):
            lane_name = f"lane{lane}"

            def attempt():
                with self.rec.span(
                    "scan_range", lane=lane_name, origin=item.origin,
                    start=item.start, stop=item.stop,
                ) as sp:
                    if self.fault_plan is not None:
                        self.fault_plan.check("shard", item.origin)
                    prefix = None
                    if item.start > 0:
                        ps = max(0, item.start - self.overlap)
                        prefix = read_range(source, ps, item.start)
                        if len(prefix) != item.start - ps:
                            raise ShortRangeRead(
                                f"range [{item.start}, {item.stop}): overlap "
                                f"prefix delivered {len(prefix)} bytes, "
                                f"expected {item.start - ps}"
                            )
                    scan = _StealableScan(
                        source, item.start, item.stop,
                        align=spec.align, piece_bytes=self.chunk_bytes,
                    )
                    sc = self._scanner_on(device, lane=lane_name)
                    with lock:
                        active[lane] = (scan, item)
                    try:
                        out = consume(
                            sc, timed_chunks(scan, item, lane_name),
                            prefix, item.start,
                        )
                    finally:
                        with lock:
                            active.pop(lane, None)
                        # sheds survive into retries (rescan only what's left)
                        # and into the missing range on exhaustion
                        item.stop = scan.retire()
                        sp.set(stop=item.stop)  # the post-shed truth
                return sc, out

            def on_failure(attempt_i, exc):
                with lock:
                    self.events.append(
                        ShardRetry(
                            shard=item.origin, attempt=attempt_i, error=repr(exc)
                        )
                    )

            sc, out = run_with_retries(
                attempt, retries=self.max_retries, on_failure=on_failure,
                is_retryable=self.is_retryable, backoff=self.backoff,
                recorder=self.rec, label=f"shard{item.origin}",
            )
            self.rec.event(
                "range_done", lane=lane_name, origin=item.origin,
                start=item.start, stop=item.stop,
            )
            with lock:
                self.dispatch_count += sc.dispatch_count
            return out

        def try_idle_steal(lane: int) -> Optional[_WorkItem]:
            with lock:
                cands = sorted(
                    active.values(), key=lambda p: -p[0].remaining()
                )
            for scan, item in cands:
                shed = scan.try_shed(self.min_steal_bytes)
                if shed is not None:
                    push_shed(item, shed, lane, "idle")
                    return _WorkItem(shed[0], shed[1], item.origin)
            return None

        def worker(lane: int, device):
            while True:
                with lock:
                    item = work.popleft() if work else None
                if item is None:
                    item = try_idle_steal(lane)
                if item is None:
                    return
                try:
                    out = scan_one(lane, device, item)
                    with lock:
                        results.append(out)
                except Exception as exc:  # noqa: BLE001 - classified upstream
                    with lock:
                        if self.on_exhausted == "partial":
                            missing.append((item.start, item.stop))
                        else:
                            errors.append(exc)
                    if self.on_exhausted == "partial":
                        self.rec.event(
                            "range_lost", lane=f"lane{lane}",
                            origin=item.origin, start=item.start,
                            stop=item.stop, error=repr(exc),
                        )
                    else:
                        return

        threads = [
            threading.Thread(
                target=worker, args=(j, lane_devices[j]),
                name=f"lane{j}", daemon=True,
            )
            for j in range(n_lanes)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]
        return results, missing

    def _partial_result(
        self, spec: StreamShardSpec, missing, *, counts=None, positions=None
    ) -> PartialScanResult:
        """Merge local missing ranges across processes and pair them with
        their complement — the covered ranges the results are exact over."""
        flat = np.asarray(
            [b for r in missing for b in r], np.int64
        ).reshape(-1)
        if jax.process_count() > 1:
            flat = np.concatenate(compat.process_allgather_ragged(flat))
        miss = merge_ranges(zip(flat[0::2].tolist(), flat[1::2].tolist()))
        return PartialScanResult(
            total_bytes=spec.total_bytes,
            covered=complement_ranges(miss, spec.total_bytes),
            missing=miss,
            counts=counts,
            positions=positions,
        )

    # -- queries ------------------------------------------------------------

    def count_many(self, source, *, total_bytes: Optional[int] = None):
        """int32 (P_total,) exact occurrence counts over the whole logical
        stream: per-shard device accumulators, one cross-device reduce, one
        cross-process psum.  Nothing syncs until the merge, so every local
        shard's chunks are in flight together.

        With ``on_exhausted="partial"`` returns a :class:`PartialScanResult`
        instead (counts exact over its covered ranges)."""
        source = _normalize_source(source)
        spec = self.shard_spec(source_total_bytes(source, total_bytes))

        def consume(sc, rs, pre, st):
            return sc.count_device(rs, prefix=pre, start=st)

        missing: List[Tuple[int, int]] = []
        if self.steal:
            parts, missing = self._elastic_run(source, spec, consume)
        else:
            parts = []
            for i in self._my_shards(spec.n_shards):
                try:
                    parts.append(self._scan_shard(source, spec, i, consume))
                except Exception:
                    if self.on_exhausted != "partial":
                        raise
                    missing.append(spec.ranges[i])
        if parts:
            local = compat.sum_across_devices(parts)
        else:  # more processes than shards: contribute zeros to the psum
            local = np.zeros((self.n_patterns,), np.int32)
        counts = compat.process_allsum(local).astype(np.int32)
        if self.on_exhausted == "partial":
            return self._partial_result(spec, missing, counts=counts)
        return counts

    def any_many(self, source, *, total_bytes: Optional[int] = None) -> np.ndarray:
        """bool (P_total,) — does each pattern occur anywhere in the stream?"""
        got = self.count_many(source, total_bytes=total_bytes)
        if isinstance(got, PartialScanResult):
            got = got.counts
        return got > 0

    def positions_many(
        self, source, *, total_bytes: Optional[int] = None
    ):
        """Per-pattern sorted global occurrence start positions.

        Each shard's masks already carry global bases, so the static-path
        merge is a concat in shard order — start ranges are disjoint across
        shards (an occurrence belongs to the shard holding its END byte, and
        ends are partitioned), hence the result is sorted without a global
        sort.  The stealing path completes ranges in arbitrary order, so it
        sorts after the concat — same multiset, same final rows.  Across
        processes, rows are exchanged via the ragged all-gather.

        With ``on_exhausted="partial"`` returns a :class:`PartialScanResult`
        (positions exact over its covered ranges)."""
        source = _normalize_source(source)
        spec = self.shard_spec(source_total_bytes(source, total_bytes))
        rows: List[List[np.ndarray]] = [[] for _ in range(self.n_patterns)]

        def consume(sc, rs, pre, st):
            return sc.positions_many(rs, prefix=pre, start=st)

        missing: List[Tuple[int, int]] = []
        if self.steal:
            outs, missing = self._elastic_run(source, spec, consume)
            for got in outs:
                for p_i in range(self.n_patterns):
                    rows[p_i].append(got[p_i])
            local = [
                np.sort(np.concatenate(r)) if r else np.zeros(0, np.int64)
                for r in rows
            ]
        else:
            for i in self._my_shards(spec.n_shards):
                try:
                    got = self._scan_shard(source, spec, i, consume)
                except Exception:
                    if self.on_exhausted != "partial":
                        raise
                    missing.append(spec.ranges[i])
                    continue
                for p_i in range(self.n_patterns):
                    rows[p_i].append(got[p_i])
            local = [
                np.concatenate(r) if r else np.zeros(0, np.int64) for r in rows
            ]
        if jax.process_count() > 1:
            local = [
                np.sort(np.concatenate(compat.process_allgather_ragged(row)))
                for row in local
            ]
        if self.on_exhausted == "partial":
            return self._partial_result(spec, missing, positions=local)
        return local


def shard_stream_count(
    source,
    patterns: Sequence,
    *,
    n_shards: Optional[int] = None,
    k: int = 0,
    chunk_bytes="auto",
    total_bytes: Optional[int] = None,
    steal: bool = False,
) -> np.ndarray:
    """int32 (P,) exact (or <= k-mismatch) sharded counts in ORIGINAL
    pattern order — the sharded sibling of :func:`stream.stream_count`."""
    plans = engine.compile_patterns_cached(list(patterns), k=k)
    sc = ShardedStreamScanner(plans, n_shards, chunk_bytes, k=k, steal=steal)
    counts = sc.count_many(source, total_bytes=total_bytes)
    out = np.zeros_like(counts)
    out[sc.order] = counts
    return out
