"""Packed Aho-Corasick automaton: the engine's linear-time dictionary
fallback (DESIGN.md §14).

The union-LUT plans (core/engine.py) are expected-case machinery: a
fingerprint-collision flood — adversarial text whose windows hash into
occupied LUT slots without matching any pattern — can push the candidate
stream toward one candidate per position, and the verify path toward its
quadratic worst case.  The classical worst-case-safe answer is a failure-
function automaton over the whole dictionary: one state transition per text
byte, O(n + occ) total, independent of how the text collides with any hash.

A sequential automaton is useless on this backend (one lax.scan step per
byte serializes the whole device).  The packed form used here exploits the
same bounded-context property the paper's packed matchers exploit: with all
patterns of length <= max_m, the Aho-Corasick state after position i is a
function of ONLY the last max_m - 1 bytes (the state encodes the longest
pattern-prefix suffix of the text, which is shorter than max_m).  So the
text splits into SEG-byte segments scanned in parallel lanes: each lane
re-derives its entry state from the root over a max_m - 1 byte overlap
prefix — by the bounded-context property it provably reaches the true
sequential state by the time it enters its own segment (pinned against the
sequential reference in kernels/acscan/ref.py) — then emits occurrences for
the segment it owns.  One lax.scan of SEG + max_m - 1 steps over a (B *
lanes,) state vector replaces n sequential steps: n / SEG - way parallelism
with vectorized gathers per step.

Two compressions keep the transition table device-friendly:

  * **byte classes** — only bytes that appear in some pattern get a class;
    all other bytes (and the virtual pre-text boundary) share class 0,
    whose transition row is identically "back to root".  The table is
    (n_states, n_classes), not (n_states, 256).
  * **CSR output lists** — occurrence emission walks a (out_off, out_ids)
    CSR of pattern ids per terminal state (suffix-chained, so nested
    patterns all fire), bounded by the static ``out_max``.

The module is deliberately engine-agnostic (no engine import): it consumes
raw (B, n) uint8 texts + lengths, so core/engine.py can lazy-import it for
the shared-path fallback without an import cycle.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

# Parallel-scan segment width: each lane owns SEG output positions and pays
# max_m - 1 warmup steps re-deriving its entry state.  128 keeps the warmup
# overhead (max_m - 1) / SEG small for every supported pattern length while
# leaving n / 128 lanes of parallelism per row.
AC_SEG = 128
# Build-time eligibility caps: exceeding any returns None from
# compile_automaton and the engine keeps its slot-dense bounded verify
# (still linear, just with the slot_max factor — DESIGN.md §14).
AC_MAX_STATES = 1 << 20
AC_MAX_CELLS = 1 << 25   # n_states * n_classes (int32 table entries)
AC_MAX_OUT = 128         # max suffix-chained emissions per state


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class AutomatonPlan:
    """Device-resident packed Aho-Corasick over one pattern dictionary."""

    delta: jnp.ndarray    # (n_states * n_classes,) int32 flat transition table
    classes: jnp.ndarray  # (256,) int32 byte -> class (0 = absent/boundary)
    out_off: jnp.ndarray  # (n_states + 1,) int32 CSR offsets into out_ids
    out_ids: jnp.ndarray  # (n_entries,) int32 pattern ids (input order)
    n_states: int         # static
    n_classes: int        # static
    n_entries: int        # static (>= 1; padded)
    out_max: int          # static: max emissions at any single state
    max_m: int            # static: longest pattern (bounded-context radius)
    n_patterns: int       # static: output column count

    def tree_flatten(self):
        return (
            (self.delta, self.classes, self.out_off, self.out_ids),
            (self.n_states, self.n_classes, self.n_entries, self.out_max,
             self.max_m, self.n_patterns),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        delta, classes, out_off, out_ids = children
        n_states, n_classes, n_entries, out_max, max_m, n_patterns = aux
        return cls(delta, classes, out_off, out_ids, n_states, n_classes,
                   n_entries, out_max, max_m, n_patterns)


def _np_patterns(patterns: Sequence) -> list:
    from repro.core.packing import as_u8_np

    rows = []
    for p in patterns:
        arr = as_u8_np(p)
        if arr.ndim != 1 or arr.size == 0:
            raise ValueError("patterns must be non-empty 1-D byte strings")
        rows.append(arr)
    return rows


def compile_automaton(
    patterns: Sequence,
    *,
    max_states: int = AC_MAX_STATES,
    max_cells: int = AC_MAX_CELLS,
    max_out: int = AC_MAX_OUT,
) -> Optional[AutomatonPlan]:
    """Build the packed automaton, or None when the dictionary blows a cap.

    Output columns are the INPUT pattern order (not plan-grouped), so the
    engine can column-select any plan subset via ``plan.ids``.  Duplicate
    patterns each get their own column (both ids sit on the shared terminal
    state's output list) — same multiplicity contract as count_many.
    """
    rows = _np_patterns(patterns)
    if not rows:
        return None
    max_m = max(len(r) for r in rows)
    total = sum(len(r) for r in rows)
    s_max = total + 1
    present = np.zeros(256, np.bool_)
    for r in rows:
        present[r] = True
    n_classes = int(present.sum()) + 1  # class 0 = absent bytes + boundary
    if s_max > max_states or s_max * n_classes > max_cells:
        return None
    classes = np.zeros(256, np.int32)
    classes[present] = np.arange(1, n_classes, dtype=np.int32)

    # --- trie (goto) over class-mapped patterns --------------------------
    goto = np.full((s_max, n_classes), -1, np.int32)
    depth = np.zeros(s_max, np.int32)
    term: list = [[]]  # state -> pattern ids ending exactly here
    n_states = 1
    for pid, r in enumerate(rows):
        s = 0
        for c in classes[r]:
            nxt = goto[s, c]
            if nxt < 0:
                nxt = n_states
                goto[s, c] = nxt
                depth[nxt] = depth[s] + 1
                term.append([])
                n_states += 1
            s = nxt
        term[s].append(pid)
    goto = goto[:n_states]
    depth = depth[:n_states]
    term_cnt = np.asarray([len(t) for t in term], np.int64)

    # --- BFS failure links, level-vectorized -----------------------------
    # delta starts as goto; each level's rows are patched from the (already
    # final) rows of their failure states, so the whole (level, n_classes)
    # slab is one numpy gather + where instead of a python cell loop.
    delta = goto.copy()
    fail = np.zeros(n_states, np.int32)
    elink = np.full(n_states, -1, np.int32)  # nearest terminal suffix state
    tot = term_cnt.copy()                    # total emissions per state
    order = np.argsort(depth, kind="stable")
    level_at = np.searchsorted(depth[order], np.arange(depth.max() + 2))
    root_row = delta[0]
    root_row[root_row < 0] = 0
    for d in range(1, int(depth.max()) + 1):
        L = order[level_at[d]:level_at[d + 1]]
        if L.size == 0:
            continue
        df = delta[fail[L]]              # (len(L), n_classes) — final rows
        rowsL = delta[L]
        miss = rowsL < 0
        children = rowsL[~miss]
        fail[children] = df[~miss]
        delta[L] = np.where(miss, df, rowsL)
        fl = fail[L]
        elink[L] = np.where(term_cnt[fl] > 0, fl, elink[fl])
        tot[L] += np.where(elink[L] >= 0, tot[np.maximum(elink[L], 0)], 0)
    out_max = int(tot.max()) if n_states else 0
    if out_max > max_out:
        return None

    # --- CSR output lists (suffix-chained) -------------------------------
    out_off = np.zeros(n_states + 1, np.int64)
    out_off[1:] = np.cumsum(tot)
    n_entries = int(out_off[-1])
    out_ids = np.zeros(max(n_entries, 1), np.int32)
    # depth order: a state's elink target is strictly shallower, so its CSR
    # region is already final when the chain below copies from it (state-id
    # order would not do — a short pattern inserted late has a HIGH id but
    # sits at LOW depth as everyone's suffix).
    for s in order[tot[order] > 0]:
        o = out_off[s]
        for pid in term[s]:
            out_ids[o] = pid
            o += 1
        e = elink[s]
        if e >= 0:
            span = tot[e]
            out_ids[o:o + span] = out_ids[out_off[e]:out_off[e] + span]

    return AutomatonPlan(
        delta=jnp.asarray(delta.reshape(-1)),
        classes=jnp.asarray(classes),
        out_off=jnp.asarray(out_off, dtype=jnp.int32),
        out_ids=jnp.asarray(out_ids),
        n_states=n_states,
        n_classes=n_classes,
        n_entries=max(n_entries, 1),
        out_max=out_max,
        max_m=max_m,
        n_patterns=len(rows),
    )


def _segment_classes(
    cls: jnp.ndarray, seg: int, ov: int
) -> Tuple[jnp.ndarray, int]:
    """(B, n) class stream -> (B, lanes, seg + ov) lane windows.  Lane L owns
    positions [L*seg, (L+1)*seg); its window starts ov bytes earlier, with
    out-of-range head positions mapped to class 0 (the boundary class, whose
    transition row is "stay at root" — exactly the sequential automaton's
    state before the first byte)."""
    B, n = cls.shape
    lanes = max(1, -(-n // seg))
    npad = lanes * seg
    cls = jnp.pad(cls, ((0, 0), (0, npad - n)))
    gpos = (
        jnp.arange(lanes, dtype=jnp.int32)[:, None] * seg
        - ov
        + jnp.arange(seg + ov, dtype=jnp.int32)[None, :]
    )  # (lanes, seg + ov)
    win = cls[:, jnp.clip(gpos, 0, npad - 1)]  # (B, lanes, seg + ov)
    return jnp.where((gpos >= 0)[None, :, :], win, 0), lanes


def automaton_states(
    text: jnp.ndarray,
    auto: AutomatonPlan,
    *,
    seg: int = AC_SEG,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """(B, n) int32 automaton state AFTER consuming each byte — bit-identical
    to the sequential scan (kernels/acscan/ref.py) by the bounded-context
    property.  ``use_kernel`` routes the transition scan through the Pallas
    acscan kernel instead of lax.scan (same states, pinned in tests)."""
    B, n = text.shape
    if n == 0:
        return jnp.zeros((B, 0), jnp.int32)
    ov = auto.max_m - 1
    cls = auto.classes[text]
    win, lanes = _segment_classes(cls, seg, ov)
    T = seg + ov
    if use_kernel:
        from repro.kernels.acscan import acscan_states

        states = acscan_states(
            win.reshape(B * lanes, T), auto.delta, auto.n_classes, seg
        ).reshape(B, lanes * seg)
        return states[:, :n]

    nclass = jnp.int32(auto.n_classes)

    def step(s, c):
        s2 = auto.delta[s * nclass + c]
        return s2, s2

    _, ys = lax.scan(
        step,
        jnp.zeros((B, lanes), jnp.int32),
        jnp.moveaxis(win, -1, 0),  # (T, B, lanes)
    )
    states = jnp.moveaxis(ys[ov:], 0, -1).reshape(B, lanes * seg)
    return states[:, :n]


def count_automaton(
    text: jnp.ndarray,
    lengths: jnp.ndarray,
    auto: AutomatonPlan,
    *,
    end_min=None,
    seg: int = AC_SEG,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """int32 (B, n_patterns) occurrence counts — input pattern order.

    Matches engine.count_many semantics exactly: an occurrence of pattern p
    (length m_p) counts when it lies fully inside the row's true length, and
    ``end_min`` keeps only occurrences ENDING at or past it (the streaming
    seam gate, which for end-position emission is just ``pos >= end_min``).
    Cost is O(n) transitions + O(n * out_max) emission — independent of the
    candidate density that drives the LUT paths' lax.cond fallbacks."""
    B, n = text.shape
    counts = jnp.zeros((B, auto.n_patterns), jnp.int32)
    if n == 0:
        return counts
    s = automaton_states(text, auto, seg=seg, use_kernel=use_kernel)
    base = auto.out_off[s]
    cnt = auto.out_off[s + 1] - base
    pos = jnp.arange(n, dtype=jnp.int32)[None, :]
    gate = pos < jnp.asarray(lengths, jnp.int32)[:, None]
    if end_min is not None:
        gate = gate & (pos >= jnp.asarray(end_min, jnp.int32))
    bix = jnp.arange(B, dtype=jnp.int32)[:, None]
    for j in range(auto.out_max):
        act = (j < cnt) & gate
        eidx = jnp.minimum(base + j, auto.n_entries - 1)
        pid = auto.out_ids[eidx]
        counts = counts.at[bix, pid].add(act.astype(jnp.int32), mode="drop")
    return counts
