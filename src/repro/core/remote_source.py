"""Reference remote range reader for sharded streaming scans (DESIGN.md §12).

``ShardedStreamScanner`` already accepts any callable ``(start, stop) ->
chunk iterator`` as a source; this module is the reference implementation of
that protocol over an S3/GCS-style "GET with a Range header" backend:

  * **parts** — a shard's byte range is fetched in ``part_bytes`` pieces
    (one object-store GET each), so a multi-GB shard never materializes a
    single giant response and a failed part retries alone;
  * **bounded prefetch** — up to ``prefetch`` parts are in flight ahead of
    the consumer on a small thread pool, hiding request latency behind the
    scan exactly like the host->device double buffer hides the copy; the
    bound keeps host memory at O(prefetch * part_bytes);
  * **per-part timeout** — a part that hasn't answered within ``timeout_s``
    is abandoned and counted as a retryable failure (the in-flight call is
    left to finish on its worker thread — the reference semantics of a soft
    deadline);
  * **retry with jittered exponential backoff, classified by error type** —
    transient I/O errors and timeouts are retried up to ``retries`` times
    per part with ``BackoffPolicy`` delays; programming errors and
    :class:`~repro.dist.fault_tolerance.FatalScanError` (auth failure,
    object gone) re-raise immediately via the same
    :func:`~repro.dist.fault_tolerance.default_is_retryable` classifier the
    shard-level retry loop uses.  A part answering the WRONG number of
    bytes is a retryable short read — never silently delivered.

The reader carries ``total_bytes``, so ``source_total_bytes`` (and hence
range partitioning) works without an extra argument, and every ``(start,
stop)`` call returns an independent iterator — re-openable, as shard retry
requires.

:class:`FakeObjectStore` is the in-process test double: a byte blob behind
a ``get_range`` RPC with optional injected faults (a ``FaultPlan`` from
``repro.dist.fault_injection``) and simulated latency, plus request
counters the tests assert on.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeoutError
from typing import Callable, Iterator, List, Optional, Tuple

import numpy as np

from repro.dist.fault_tolerance import (
    BackoffPolicy,
    default_is_retryable,
)
from repro.obs.recorder import NULL as _NULL_REC

DEFAULT_PART_BYTES = 1 << 20


class RangeReadTimeout(IOError):
    """A part fetch exceeded the reader's per-range timeout.  An IOError:
    timeouts are the canonical retryable failure."""


@dataclasses.dataclass
class RemoteReadStats:
    """Counters a scan can assert on / a dashboard can scrape."""

    gets: int = 0          # part fetches issued (including retries)
    parts: int = 0         # parts delivered to the consumer
    bytes: int = 0         # payload bytes delivered
    retries: int = 0       # failed attempts that were retried
    timeouts: int = 0      # attempts abandoned at the deadline


class RemoteRangeReader:
    """Callable ``(start, stop) -> iterator of uint8 arrays`` over a
    ``fetch(start, stop) -> bytes`` backend (one object-store GET per call).

    ``fetch`` must be thread-safe: prefetched parts are issued from a small
    worker pool.  ``sleep`` and the ``backoff`` policy's seed are injectable
    so tests can assert the exact backoff schedule without waiting it out.
    """

    def __init__(
        self,
        fetch: Callable[[int, int], bytes],
        total_bytes: Optional[int] = None,
        *,
        part_bytes: int = DEFAULT_PART_BYTES,
        prefetch: int = 2,
        timeout_s: Optional[float] = 30.0,
        retries: int = 4,
        backoff: Optional[BackoffPolicy] = None,
        is_retryable=None,
        sleep=time.sleep,
        recorder=None,
    ):
        if part_bytes < 1:
            raise ValueError("part_bytes must be >= 1")
        if prefetch < 1:
            raise ValueError("prefetch must be >= 1 (1 = no look-ahead)")
        if retries < 0:
            raise ValueError("retries must be >= 0")
        if total_bytes is None:
            total_bytes = getattr(fetch, "total_bytes", None)
        if total_bytes is None:
            raise ValueError(
                "RemoteRangeReader needs total_bytes (pass it, or give the "
                "fetch backend a total_bytes attribute)"
            )
        self.fetch = fetch
        self.total_bytes = int(total_bytes)
        self.part_bytes = int(part_bytes)
        self.prefetch = int(prefetch)
        self.timeout_s = timeout_s
        self.retries = int(retries)
        self.backoff = BackoffPolicy() if backoff is None else backoff
        self.is_retryable = (
            default_is_retryable if is_retryable is None else is_retryable
        )
        self.sleep = sleep
        self.stats = RemoteReadStats()
        # flight recorder (repro.obs): part waits become spans on the
        # consuming lane, timeouts/retries become structured events next to
        # the stats counters.  Defaults to the shared disabled recorder.
        self.rec = _NULL_REC if recorder is None else recorder
        self._lock = threading.Lock()

    # -- per-part fetch with timeout + classified backoff retry -------------

    def _resolve(self, ex: ThreadPoolExecutor, fut, s: int, e: int) -> bytes:
        """Resolve one part: attempt 0 consumes the prefetched future, each
        retry submits a fresh fetch after the classified backoff delay."""
        for attempt in range(self.retries + 1):
            if fut is None:
                with self._lock:
                    self.stats.gets += 1
                fut = ex.submit(self.fetch, s, e)
            try:
                data = fut.result(timeout=self.timeout_s)
                if len(data) != e - s:
                    # short/overlong response: retryable, never delivered
                    raise IOError(
                        f"part [{s}, {e}) returned {len(data)} bytes, "
                        f"expected {e - s}"
                    )
                return data
            except Exception as exc:  # noqa: BLE001 - classified below
                if isinstance(exc, FutureTimeoutError):
                    fut.cancel()  # queued attempts die; running ones are abandoned
                    with self._lock:
                        self.stats.timeouts += 1
                    exc = RangeReadTimeout(
                        f"part [{s}, {e}) exceeded timeout_s={self.timeout_s}"
                    )
                    self.rec.event(
                        "part_timeout", start=s, stop=e, attempt=attempt
                    )
                if attempt == self.retries or not self.is_retryable(exc):
                    raise exc
                with self._lock:
                    self.stats.retries += 1
                self.rec.event(
                    "part_retry", start=s, stop=e, attempt=attempt,
                    error=repr(exc),
                )
                self.rec.count("remote_part_retries")
                self.sleep(self.backoff.delay_s(attempt))
                fut = None
        raise AssertionError("unreachable")

    # -- the (start, stop) protocol ----------------------------------------

    def __call__(self, start: int, stop: int) -> Iterator[np.ndarray]:
        start, stop = int(start), int(stop)
        if not (0 <= start <= stop <= self.total_bytes):
            raise ValueError(
                f"bad range [{start}, {stop}) of {self.total_bytes} bytes"
            )
        parts: List[Tuple[int, int]] = [
            (s, min(s + self.part_bytes, stop))
            for s in range(start, stop, self.part_bytes)
        ]

        def gen():
            # pool sized past the prefetch bound so a retry after an
            # abandoned (still-running) timeout attempt can still schedule
            with ThreadPoolExecutor(max_workers=self.prefetch + 2) as ex:
                inflight: List[Tuple[Tuple[int, int], object]] = []
                nxt = 0
                while inflight or nxt < len(parts):
                    while nxt < len(parts) and len(inflight) < self.prefetch:
                        s, e = parts[nxt]
                        with self._lock:
                            self.stats.gets += 1
                        inflight.append(((s, e), ex.submit(self.fetch, s, e)))
                        nxt += 1
                    (s, e), fut = inflight.pop(0)
                    with self.rec.span(
                        "part_wait", start=s, stop=e
                    ) as sp:
                        data = self._resolve(ex, fut, s, e)
                        sp.set(bytes=len(data))
                    with self._lock:
                        self.stats.parts += 1
                        self.stats.bytes += len(data)
                    self.rec.count("remote_parts")
                    self.rec.count("remote_bytes", len(data))
                    yield np.frombuffer(data, np.uint8)

        return gen()


class FakeObjectStore:
    """In-process stand-in for a blob store: ``get_range(start, stop)``
    over a byte buffer, with optional simulated latency and injected faults
    (any object with the ``FaultPlan`` ``check``/``truncate`` shape — site
    kind ``"remote_get"``, key ``(start, stop)``).  Thread-safe; counts
    requests so tests can assert prefetch/retry behavior."""

    def __init__(self, data, *, plan=None, latency_s: float = 0.0, sleep=time.sleep):
        self.data = np.asarray(
            np.frombuffer(bytes(data), np.uint8)
            if isinstance(data, (bytes, bytearray, memoryview))
            else data,
            dtype=np.uint8,
        ).reshape(-1)
        self.plan = plan
        self.latency_s = latency_s
        self.sleep = sleep
        self.gets = 0
        self.bytes_served = 0
        self._lock = threading.Lock()

    @property
    def total_bytes(self) -> int:
        return len(self.data)

    def get_range(self, start: int, stop: int) -> bytes:
        with self._lock:
            self.gets += 1
        if self.latency_s:
            self.sleep(self.latency_s)
        if self.plan is not None:
            self.plan.check("remote_get", (start, stop))
        data = self.data[start:stop].tobytes()
        if self.plan is not None:
            keep = self.plan.truncate("remote_get", (start, stop), len(data))
            data = data[:keep]
        with self._lock:
            self.bytes_served += len(data)
        return data

    def reader(self, **kwargs) -> RemoteRangeReader:
        """A RemoteRangeReader over this store (its fetch is ``get_range``)."""
        return RemoteRangeReader(self.get_range, self.total_bytes, **kwargs)
