"""Multi-pattern packed matching (extension of the paper; cf. Faro & Kulekci,
"Fast multiple string matching using streaming SIMD extensions technology",
SPIRE 2012 — reference [10] of the paper).

Two layers live here:

  * the *vmap baseline*: stack equal-length patterns into (P, m) and vmap
    the single-pattern scan over them.  XLA shares the text-side packing
    across the vmap, but every position still pays O(P) compare work.  Kept
    as `find_multi_vmap` / `count_multi_vmap` — it is the benchmark baseline
    and the semantic reference.

  * the engine path (repro.core.engine): pack + fingerprint the text ONCE
    (TextIndex), compile each length group ONCE (PatternPlan), and answer
    all P patterns x B texts per device dispatch, with per-position filter
    cost independent of P.  `find_multi`, `count_multi`, `contains_any`, and
    `PatternSet` all route through it.

Used by the data pipeline for blocklist filtering (DESIGN.md §4, §7).
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import engine, epsm
from repro.core.packing import as_u8


# ---------------------------------------------------------------------------
# vmap baseline (previous hot path; now the reference + benchmark baseline)
# ---------------------------------------------------------------------------

def find_multi_vmap(text, patterns, *, algo: str = "auto") -> jnp.ndarray:
    """Per-pattern vmapped scan: bool[P, n].  O(P * n) compare work."""
    t = as_u8(text)
    ps = as_u8(patterns)
    if ps.ndim != 2:
        raise ValueError("patterns must be (P, m)")
    return jax.vmap(lambda p: epsm.find(t, p, algo=algo))(ps)


def count_multi_vmap(text, patterns, *, algo: str = "auto") -> jnp.ndarray:
    return find_multi_vmap(text, patterns, algo=algo).sum(axis=-1, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Engine-backed API
# ---------------------------------------------------------------------------

def _stack_plans(patterns):
    ps = np.asarray(jax.device_get(as_u8(patterns)))
    if ps.ndim != 2:
        raise ValueError("patterns must be (P, m)")
    return engine.compile_patterns_cached(list(ps))


def find_multi(text, patterns, *, algo: str = "auto") -> jnp.ndarray:
    """Match-start masks for a (P, m) stack of equal-length patterns.

    Returns bool[P, n].  One shared-text dispatch via the engine; the plan
    build is memoized on the pattern bytes.  NOT itself jit-traceable (plan
    compilation is host-side) — inside jit, pre-compile plans and call
    ``engine.match_many`` directly, as PatternSet and the serving engine do.
    """
    del algo  # regime is selected per length group by the engine
    plans = _stack_plans(patterns)
    idx = engine.build_index(as_u8(text))
    return engine.match_many_jit(idx, plans)[0]


def count_multi(text, patterns, *, algo: str = "auto") -> jnp.ndarray:
    del algo
    plans = _stack_plans(patterns)
    idx = engine.build_index(as_u8(text))
    return engine.count_many_jit(idx, plans)[0]


def contains_any(text, patterns, *, algo: str = "auto") -> jnp.ndarray:
    """Scalar bool: does any of the stacked patterns occur in text?"""
    del algo
    plans = _stack_plans(patterns)
    idx = engine.build_index(as_u8(text))
    return engine.count_many_jit(idx, plans).sum() > 0


class PatternSet:
    """Blocklist over patterns of arbitrary (mixed) lengths.

    Compiles every length group into a PatternPlan ONCE at construction; all
    queries afterwards are single engine dispatches over all groups at once
    (the seed implementation issued one dispatch per length group).  This is
    the object the data pipeline holds on to.

    ``k`` is a Hamming mismatch budget (repro.approx, DESIGN.md §8): a
    k-compiled set treats a document as blocked when any pattern occurs
    within <= k byte substitutions — typo-tolerant blocklists for free,
    since every query below flows through the engine's per-plan default.
    """

    def __init__(
        self,
        patterns: Sequence,
        *,
        k: int = 0,
        bucket="auto",
        automaton="auto",
        recorder=None,
    ):
        if not patterns:
            raise ValueError("empty PatternSet")
        self.k = int(k)
        # bucket/automaton/recorder pass straight through to the engine's
        # dictionary-scale plan compiler (DESIGN.md §14) — the defaults keep
        # small sets on the flat payload LUTs, bit-identically.
        self.plans = engine.compile_patterns(
            patterns, k=self.k, bucket=bucket, automaton=automaton,
            recorder=recorder,
        )
        self.order = engine.plan_order(self.plans)
        # group-major (seed-compatible) order of the original patterns
        self.groups = {p.m: p.patterns for p in self.plans}
        self._scanners: dict = {}  # chunk_bytes -> StreamScanner (reusable)

    def index(self, text_or_batch, lengths=None) -> engine.TextIndex:
        return engine.build_index(text_or_batch, lengths)

    def contains_any(self, text) -> jnp.ndarray:
        """Scalar bool for a single text (seed API)."""
        idx = engine.build_index(as_u8(text))
        return engine.count_many_jit(idx, self.plans).sum() > 0

    def blocked(self, texts, lengths=None) -> jnp.ndarray:
        """bool[B] blocklist verdicts for a padded (B, L) document batch —
        one fused device dispatch for the whole batch x all patterns."""
        if lengths is None:
            idx = engine.build_index(texts)
            return engine.count_many_jit(idx, self.plans).sum(-1) > 0
        return engine.blocked(texts, lengths, self.plans)

    def count_each(self, text) -> jnp.ndarray:
        """Concatenated per-pattern occurrence counts (group order)."""
        idx = engine.build_index(as_u8(text))
        return engine.count_many_jit(idx, self.plans)[0]

    def contains_any_stream(self, source, *, chunk_bytes: int = 1 << 22) -> bool:
        """Bounded-memory verdict for one oversize document or byte stream
        (repro.core.stream, DESIGN.md §9): O(chunk_bytes) device memory
        regardless of document length, with early exit on a hit.  The
        scanner (and its jit trace) is cached per chunk size, so a corpus
        of oversize documents pays the setup once."""
        sc = self._scanners.get(chunk_bytes)
        if sc is None:
            from repro.core.stream import StreamScanner

            sc = StreamScanner(self.plans, chunk_bytes, k=self.k)
            self._scanners[chunk_bytes] = sc
        return sc.contains_any(source)
