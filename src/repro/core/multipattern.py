"""Multi-pattern packed matching (extension of the paper; cf. Faro & Kulekci,
"Fast multiple string matching using streaming SIMD extensions technology",
SPIRE 2012 — reference [10] of the paper).

Patterns of equal length are stacked into a (P, m) matrix and searched with a
single vmapped packed scan; the text-side packing (pack_u32 / fingerprints)
is pattern-independent so it is computed once and shared across all P
patterns (vmap with in_axes=None on the text broadcasts it).

Used by the data pipeline for blocklist filtering (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import epsm
from repro.core.packing import as_u8


def find_multi(text, patterns, *, algo: str = "auto") -> jnp.ndarray:
    """Match-start masks for a (P, m) stack of equal-length patterns.

    Returns bool[P, n].
    """
    t = as_u8(text)
    ps = as_u8(patterns)
    if ps.ndim != 2:
        raise ValueError("patterns must be (P, m)")
    return jax.vmap(lambda p: epsm.find(t, p, algo=algo))(ps)


def count_multi(text, patterns, *, algo: str = "auto") -> jnp.ndarray:
    return find_multi(text, patterns, algo=algo).sum(axis=-1, dtype=jnp.int32)


def contains_any(text, patterns, *, algo: str = "auto") -> jnp.ndarray:
    """Scalar bool: does any of the stacked patterns occur in text?"""
    return find_multi(text, patterns, algo=algo).any()


class PatternSet:
    """Blocklist over patterns of arbitrary (mixed) lengths.

    Groups patterns by length so each group becomes one stacked packed scan.
    This is the object the data pipeline holds on to.
    """

    def __init__(self, patterns: Sequence):
        groups: dict[int, list[np.ndarray]] = {}
        for p in patterns:
            arr = np.asarray(jax.device_get(as_u8(p)))
            if arr.size == 0:
                raise ValueError("empty pattern in PatternSet")
            groups.setdefault(arr.size, []).append(arr)
        self.groups = {
            m: jnp.asarray(np.stack(ps)) for m, ps in sorted(groups.items())
        }

    def contains_any(self, text) -> jnp.ndarray:
        t = as_u8(text)
        hit = jnp.asarray(False)
        for stack in self.groups.values():
            hit = hit | contains_any(t, stack)
        return hit

    def count_each(self, text) -> jnp.ndarray:
        """Concatenated per-pattern occurrence counts (group order)."""
        t = as_u8(text)
        counts = [count_multi(t, stack) for stack in self.groups.values()]
        return jnp.concatenate(counts) if counts else jnp.zeros((0,), jnp.int32)
