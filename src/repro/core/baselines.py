"""Baseline exact string-matching algorithms the paper compares against.

The paper's experimental section (Tables 1-3) pits EPSM against the best
algorithms of the Faro-Lecroq survey.  We implement the representative set
that transfers to a JAX/TPU word-RAM model:

  * ``naive_np``      — scalar numpy oracle (tests only).
  * ``packed_naive``  — vectorized shifted-AND over the full pattern (what
                        "naive" becomes once you have wide vector compares).
  * ``shift_or``      — SO [Baeza-Yates & Gonnet 1992]: bit-parallel NFA,
                        O(n ceil(m/w)); sequential scan ==> lax.scan.
  * ``kmp_dfa``       — KMP as a DFA table + lax.scan (the O(n) classic).
  * ``rabin_karp``    — rolling-hash filter + verification (the closest
                        classical relative of EPSMc).
  * ``hash3``         — Lecroq's HASHq (q=3) skip-loop [Lecroq 2007];
                        data-dependent skips ==> lax.while_loop (kept faithful:
                        this is *exactly* the control flow TPUs dislike, and
                        the benchmark quantifies that).
  * ``bndm``          — Backward Nondeterministic DAWG Matching [Navarro &
                        Raffinot 1998], bit-parallel suffix automaton with
                        skips; nested lax.while_loop.  m <= 31 (one word).

Skip-based algorithms (hash3, bndm) take concrete (host) patterns because
their tables are built with data-dependent python loops, mirroring real
implementations where preprocessing is scalar code.  Scan/vector algorithms
accept traced patterns.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.packing import as_u8, shift_left, valid_start_mask


def _concrete_u8(pattern) -> np.ndarray:
    """Host-side pattern bytes (table-building preprocessing is scalar code).

    Must run on a CONCRETE pattern even when the search is jit-traced — so
    convert via numpy BEFORE any jnp op (jnp constants become tracers
    inside a trace)."""
    if isinstance(pattern, str):
        pattern = pattern.encode()
    if isinstance(pattern, (bytes, bytearray, memoryview)):
        return np.frombuffer(bytes(pattern), np.uint8)
    if isinstance(pattern, np.ndarray):
        return pattern.astype(np.uint8)
    return np.asarray(jax.device_get(pattern)).astype(np.uint8)


# ---------------------------------------------------------------------------
# Scalar oracle (numpy; used by tests and to define ground truth)
# ---------------------------------------------------------------------------

def naive_np(text, pattern) -> np.ndarray:
    t = np.asarray(jax.device_get(as_u8(text)))
    p = np.asarray(jax.device_get(as_u8(pattern)))
    n, m = len(t), len(p)
    mask = np.zeros(n, dtype=bool)
    for i in range(n - m + 1):
        if np.array_equal(t[i : i + m], p):
            mask[i] = True
    return mask


# ---------------------------------------------------------------------------
# Vectorized baselines
# ---------------------------------------------------------------------------

def packed_naive(text, pattern) -> jnp.ndarray:
    """Shifted-AND over all m characters (EPSMa generalized to any m)."""
    t, p = as_u8(text), as_u8(pattern)
    n, m = t.shape[0], p.shape[0]
    if n < m:
        return jnp.zeros((n,), dtype=jnp.bool_)
    acc = jnp.ones((n,), dtype=jnp.bool_)
    for j in range(m):
        acc = acc & (shift_left(t, j) == p[j])
    return acc & valid_start_mask(n, m)


def shift_or(text, pattern) -> jnp.ndarray:
    """SO: D' = (D << 1) | B[c]; match-end when bit m-1 of D is clear."""
    t, p = as_u8(text), as_u8(pattern)
    n, m = t.shape[0], p.shape[0]
    if m > 32:
        raise ValueError("shift_or supports m <= 32 (single 32-bit word)")
    if n < m:
        return jnp.zeros((n,), dtype=jnp.bool_)
    cs = jnp.arange(256, dtype=jnp.uint8)
    # B[c] bit j set <=> p[j] != c ; bits are distinct so sum == OR.
    bits = (p[None, :] != cs[:, None]).astype(jnp.uint32) << jnp.arange(m, dtype=jnp.uint32)[None, :]
    B = bits.sum(axis=1).astype(jnp.uint32)  # (256,)

    def step(D, c):
        D = (D << jnp.uint32(1)) | B[c]
        return D, (D >> jnp.uint32(m - 1)) & jnp.uint32(1)

    _, mism = lax.scan(step, jnp.uint32(0xFFFFFFFF), t)
    match_end = mism == 0  # (n,) True where an occurrence ENDS
    # start mask: start i <=> end i+m-1
    return shift_left(match_end, m - 1) & valid_start_mask(n, m)


def _kmp_table(p: np.ndarray) -> np.ndarray:
    m = len(p)
    dfa = np.zeros((m + 1, 256), dtype=np.int32)
    dfa[0, p[0]] = 1
    x = 0
    for j in range(1, m):
        dfa[j, :] = dfa[x, :]
        dfa[j, p[j]] = j + 1
        x = dfa[x, p[j]]
    # after a full match continue from the border state
    dfa[m, :] = dfa[x, :]
    dfa[m, p[x] if x < m else 0] = dfa[x, p[x]] if x < m else dfa[m, 0]
    return dfa


def kmp_dfa(text, pattern) -> jnp.ndarray:
    """KMP compiled to a (m+1) x 256 DFA, searched with one lax.scan."""
    t = as_u8(text)
    p = _concrete_u8(pattern)
    n, m = t.shape[0], len(p)
    if n < m:
        return jnp.zeros((n,), dtype=jnp.bool_)
    dfa = jnp.asarray(_kmp_table(p))

    def step(s, c):
        s = dfa[s, c]
        return s, s == m

    _, match_end = lax.scan(step, jnp.int32(0), t)
    return shift_left(match_end, m - 1) & valid_start_mask(n, m)


def rabin_karp(text, pattern, *, base: int = 1000003) -> jnp.ndarray:
    """Karp-Rabin mod-2^32 rolling hash filter + exact verification."""
    t, p = as_u8(text), as_u8(pattern)
    n, m = t.shape[0], p.shape[0]
    if n < m:
        return jnp.zeros((n,), dtype=jnp.bool_)
    w = jnp.power(jnp.uint32(base), jnp.arange(m - 1, -1, -1, dtype=jnp.uint32))
    h = jnp.zeros((n,), dtype=jnp.uint32)
    for j in range(m):
        h = h + shift_left(t, j).astype(jnp.uint32) * w[j]
    hp = (p.astype(jnp.uint32) * w).sum(dtype=jnp.uint32)
    cand = (h == hp) & valid_start_mask(n, m)
    # exact verification of candidates (dense masked)
    ok = cand
    for j in range(m):
        ok = ok & (shift_left(t, j) == p[j])
    return ok


# ---------------------------------------------------------------------------
# Skip-loop baselines (sequential; lax.while_loop)
# ---------------------------------------------------------------------------

def _hash3_tables(p: np.ndarray, hs: int = 4096):
    m = len(p)
    q = 3

    def h(c0, c1, c2):
        return (int(c0) + (int(c1) << 3) + (int(c2) << 6)) & (hs - 1)

    shift = np.full(hs, m - q + 1, dtype=np.int32)
    # q-gram ending at pattern position j+q-1 allows shift m-1-(j+q-1)
    for j in range(m - q + 1):
        v = h(p[j], p[j + 1], p[j + 2])
        shift[v] = min(shift[v], m - 1 - (j + q - 1))
    return shift


def hash3(text, pattern) -> jnp.ndarray:
    """Lecroq HASHq (q=3): Wu-Manber style q-gram shift table + skip loop."""
    t = as_u8(text)
    p_np = _concrete_u8(pattern)
    n, m = t.shape[0], len(p_np)
    if m < 3:
        return packed_naive(t, p_np)
    if n < m:
        return jnp.zeros((n,), dtype=jnp.bool_)
    shift = jnp.asarray(_hash3_tables(p_np))
    p = jnp.asarray(p_np)

    def hv(i):  # hash of q-gram ending at i
        c0 = t[i - 2].astype(jnp.int32)
        c1 = t[i - 1].astype(jnp.int32)
        c2 = t[i].astype(jnp.int32)
        return (c0 + (c1 << 3) + (c2 << 6)) & (4096 - 1)

    def cond(state):
        i, _ = state
        return i < n

    def body(state):
        i, mask = state
        s = shift[hv(i)]
        at_cand = s == 0
        start = i - m + 1
        window = lax.dynamic_slice(t, (jnp.maximum(start, 0),), (m,))
        hit = at_cand & (start >= 0) & jnp.all(window == p)
        mask = mask.at[jnp.where(hit, start, n)].set(True, mode="drop")
        i = i + jnp.where(at_cand, 1, s)
        return i, mask

    i0 = jnp.int32(m - 1)
    mask0 = jnp.zeros((n,), dtype=jnp.bool_)
    _, mask = lax.while_loop(cond, body, (i0, mask0))
    return mask


def bndm(text, pattern) -> jnp.ndarray:
    """BNDM: bit-parallel suffix automaton with window skips (m <= 31)."""
    t = as_u8(text)
    p_np = _concrete_u8(pattern)
    n, m = t.shape[0], len(p_np)
    if m > 31:
        raise ValueError("bndm supports m <= 31 (single 32-bit word)")
    if n < m:
        return jnp.zeros((n,), dtype=jnp.bool_)
    B_np = np.zeros(256, dtype=np.uint32)
    for j in range(m):
        B_np[p_np[j]] |= np.uint32(1) << np.uint32(m - 1 - j)
    B = jnp.asarray(B_np)
    top = jnp.uint32(1) << jnp.uint32(m - 1)

    def outer_cond(state):
        pos, _ = state
        return pos <= n - m

    def outer_body(state):
        pos, mask = state

        def inner_cond(s):
            _, D, _, _ = s
            return D != 0

        def inner_body(s):
            j, D, last, mask = s
            D = D & B[t[pos + j - 1]]
            j = j - 1
            hit = (D & top) != 0
            is_match = hit & (j == 0)
            mask = mask.at[jnp.where(is_match, pos, n)].set(True, mode="drop")
            last = jnp.where(hit & (j > 0), j, last)
            D = jnp.where(j > 0, D << jnp.uint32(1), jnp.uint32(0))
            return j, D, last, mask

        j0 = jnp.int32(m)
        D0 = jnp.uint32(0xFFFFFFFF) >> jnp.uint32(32 - m)
        _, _, last, mask = lax.while_loop(
            inner_cond, inner_body, (j0, D0, jnp.int32(m), mask)
        )
        return pos + last, mask

    mask0 = jnp.zeros((n,), dtype=jnp.bool_)
    _, mask = lax.while_loop(outer_cond, outer_body, (jnp.int32(0), mask0))
    return mask


BASELINES = {
    "packed_naive": packed_naive,
    "shift_or": shift_or,
    "kmp_dfa": kmp_dfa,
    "rabin_karp": rabin_karp,
    "hash3": hash3,
    "bndm": bndm,
}
