"""Scan-aware FLOP / HBM-traffic estimation from jaxprs.

XLA's HloCostAnalysis visits each while-loop body ONCE, so scan-over-layers
programs (ours: 30-64 layer scans, chunked-attention scans, chunked-CE scans,
MoE group maps) are undercounted by 1-2 orders of magnitude on the CPU
backend (verified empirically; see EXPERIMENTS.md §Dry-run methodology).

This walker recurses through scan (x length), cond (max branch), pjit /
remat / custom_*-calls, and counts:

  flops:
    * dot_general: 2 * batch * M * N * K
    * elementwise / reduce: 1 flop per output (resp. input) element
  bytes (post-fusion HBM traffic model — elementwise ops are assumed fused):
    * dot_general: operands + result
    * gather: result + indices        (a gather reads rows, not the table)
    * scatter: updates + result
    * dynamic_update_slice: 2x update (read+write)
    * dynamic_slice / reduce: result (resp. operand + result)

Validated against compiled.cost_analysis() on fully-unrolled probes, where
HLO cost analysis is exact (tests/test_roofline_cost.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import numpy as np

import jax
from jax import core as jcore


@dataclasses.dataclass
class Cost:
    """mxu_flops: dot_general work (systolic array); vpu_flops: everything
    elementwise/reduce (vector units, ~50x lower peak than the MXU)."""

    mxu_flops: float = 0.0
    vpu_flops: float = 0.0
    bytes: float = 0.0

    @property
    def flops(self) -> float:
        return self.mxu_flops + self.vpu_flops

    def __add__(self, o):
        return Cost(
            self.mxu_flops + o.mxu_flops,
            self.vpu_flops + o.vpu_flops,
            self.bytes + o.bytes,
        )

    def __mul__(self, k: float):
        return Cost(self.mxu_flops * k, self.vpu_flops * k, self.bytes * k)


def _nbytes(aval) -> float:
    try:
        return float(np.prod(aval.shape) * aval.dtype.itemsize)
    except Exception:
        return 0.0


def _numel(aval) -> float:
    try:
        return float(np.prod(aval.shape))
    except Exception:
        return 0.0


_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "pow", "rem", "and", "or",
    "xor", "not", "neg", "exp", "log", "log1p", "tanh", "logistic", "sqrt",
    "rsqrt", "abs", "sign", "floor", "ceil", "round", "cos", "sin", "erf",
    "integer_pow", "select_n", "clamp", "nextafter", "cbrt", "square",
    "atan2", "expm1", "cumsum", "cumlogsumexp", "cummax", "cumprod",
}

_COMPARE = {"eq", "ne", "lt", "le", "gt", "ge"}

_FREE = {
    "broadcast_in_dim", "reshape", "transpose", "convert_element_type",
    "slice", "squeeze", "rev", "iota", "stop_gradient", "copy",
    "bitcast_convert_type", "concatenate", "pad", "expand_dims",
    "device_put", "sharding_constraint", "split",
}


def _dot_general_cost(eqn) -> Cost:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    batch = np.prod([a.shape[i] for i in lb]) if lb else 1.0
    k = np.prod([a.shape[i] for i in lc]) if lc else 1.0
    m = np.prod([d for i, d in enumerate(a.shape) if i not in set(lc) | set(lb)])
    n = np.prod([d for i, d in enumerate(b.shape) if i not in set(rc) | set(rb)])
    out = eqn.outvars[0].aval
    flops = 2.0 * batch * m * n * k
    byts = _nbytes(a) + _nbytes(b) + _nbytes(out)
    return Cost(mxu_flops=flops, bytes=byts)


def _sub_jaxprs(params):
    """Collect Jaxpr/ClosedJaxpr values (incl. inside tuples) from params."""
    found = []

    def visit(v):
        if hasattr(v, "eqns"):  # Jaxpr
            found.append(v)
        elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):  # ClosedJaxpr
            found.append(v.jaxpr)
        elif isinstance(v, (tuple, list)):
            for x in v:
                visit(x)

    for v in params.values():
        visit(v)
    return found


def jaxpr_cost(jaxpr, mult: float = 1.0) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        total = total + eqn_cost(eqn) * 1.0
    return total * mult


def eqn_cost(eqn) -> Cost:
    prim = eqn.primitive.name

    if prim == "dot_general":
        return _dot_general_cost(eqn)

    if prim == "scan":
        length = eqn.params["length"]
        inner = jaxpr_cost(eqn.params["jaxpr"].jaxpr)
        return inner * float(length)

    if prim == "while":
        # not used in model code; assume trip count 1 (flagged elsewhere)
        return jaxpr_cost(eqn.params["body_jaxpr"].jaxpr)

    if prim == "cond":
        branches = eqn.params["branches"]
        costs = [jaxpr_cost(b.jaxpr) for b in branches]
        return max(costs, key=lambda c: c.flops) if costs else Cost()

    # generic recursion: any primitive carrying sub-jaxprs (pjit, remat2,
    # custom_vjp_call, shard_map, ...) costs the sum of its bodies
    subs = _sub_jaxprs(eqn.params)
    if subs:
        total = Cost()
        for j in subs:
            total = total + jaxpr_cost(j)
        return total

    out = eqn.outvars[0].aval if eqn.outvars else None

    if prim == "gather":
        idx = eqn.invars[1].aval
        return Cost(0.0, 0.0, (_nbytes(out) if out is not None else 0.0) + _nbytes(idx))

    if prim in ("scatter", "scatter-add", "scatter_add", "scatter_max",
                "scatter_min", "scatter_mul"):
        upd = eqn.invars[2].aval
        return Cost(0.0, _numel(upd), _nbytes(upd) + (_nbytes(out) if out is not None else 0.0))

    if prim == "dynamic_update_slice":
        upd = eqn.invars[1].aval
        return Cost(0.0, 0.0, 2.0 * _nbytes(upd))

    if prim == "dynamic_slice":
        return Cost(0.0, 0.0, _nbytes(out) if out is not None else 0.0)

    if prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                "reduce_and", "reduce_or", "argmax", "argmin", "reduce",
                "reduce_precision"):
        op = eqn.invars[0].aval
        return Cost(0.0, _numel(op), _nbytes(op) + (_nbytes(out) if out is not None else 0.0))

    if prim == "sort":
        op = eqn.invars[0].aval
        n = _numel(op)
        return Cost(0.0, n * max(np.log2(max(n, 2)), 1.0), 2.0 * _nbytes(op))

    if prim in _ELEMENTWISE or prim in _COMPARE:
        return Cost(0.0, _numel(out) if out is not None else 0.0, 0.0)

    if prim in _FREE:
        return Cost()

    # unknown primitive: elementwise-ish fallback
    return Cost(0.0, _numel(out) if out is not None else 0.0, 0.0)


def step_cost(fn, *args) -> Dict[str, float]:
    """Trace fn(*args) (ShapeDtypeStructs fine) and estimate global cost."""
    closed = jax.make_jaxpr(fn)(*args)
    c = jaxpr_cost(closed.jaxpr)
    return {
        "flops": c.flops,
        "mxu_flops": c.mxu_flops,
        "vpu_flops": c.vpu_flops,
        "bytes": c.bytes,
    }
