"""Roofline-term derivation from compiled dry-run artifacts (DESIGN.md §7).

Hardware model: TPU v5e —
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

Terms (per chip; cost_analysis of the SPMD-partitioned module is already
per-device):
    compute    = HLO_FLOPs / peak_FLOPs
    memory     = HLO_bytes / HBM_bw
    collective = collective_bytes / link_bw

collective_bytes is parsed from the post-SPMD HLO: for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute we
take max(operand bytes, result bytes) — operands measure what each device
contributes, results what it receives; max is the per-device traffic proxy
(all-reduce moves ~2x operand in a ring; we report the raw term and note the
ring factor in EXPERIMENTS.md).
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 per chip (MXU)
VPU_FLOPS = 3.9e12  # elementwise/reduce peak (~= MXU/50; vector units)
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "f16": 2,
    "bf16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

_COLL_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str) -> Dict[str, dict]:
    """Per-op-kind operand/result byte totals from post-partitioning HLO."""
    stats = {
        op: {"count": 0, "operand_bytes": 0, "result_bytes": 0} for op in _COLL_OPS
    }
    for line in hlo_text.splitlines():
        for op in _COLL_OPS:
            marker = f" {op}("
            start_marker = f" {op}-start("
            if marker not in line and start_marker not in line:
                continue
            # skip -done ops (they restate the -start shapes)
            if f"{op}-done" in line:
                continue
            eq = line.find("=")
            if eq < 0:
                continue
            lhs_call = line.find(op, eq)
            result_part = line[eq + 1 : lhs_call]
            paren = line.find("(", lhs_call)
            operand_part = line[paren : line.rfind(")") + 1]
            # strip metadata clauses that could contain shapes
            operand_part = operand_part.split("replica_groups")[0]
            stats[op]["count"] += 1
            stats[op]["result_bytes"] += _shape_bytes(result_part)
            stats[op]["operand_bytes"] += _shape_bytes(operand_part)
            break
    return stats


def collective_bytes(stats: Dict[str, dict]) -> int:
    return sum(
        max(s["operand_bytes"], s["result_bytes"]) for s in stats.values()
    )


def roofline_terms(
    hlo_flops_per_chip: float,
    hlo_bytes_per_chip: float,
    coll_bytes_per_chip: float,
    vpu_flops_per_chip: float = 0.0,
) -> Dict[str, float]:
    # hlo_flops = MXU (dot) flops when vpu_flops is passed separately
    compute = hlo_flops_per_chip / PEAK_FLOPS + vpu_flops_per_chip / VPU_FLOPS
    memory = hlo_bytes_per_chip / HBM_BW
    collective = coll_bytes_per_chip / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dominant = max(terms, key=terms.get)
    terms["bottleneck"] = dominant.replace("_s", "")
    total = max(compute, memory, collective)
    terms["roofline_fraction_compute"] = compute / total if total > 0 else 0.0
    return terms


def summarize(record: dict) -> str:
    t = record["roofline"]
    return (
        f"{record['arch']}/{record['shape']}@{record['mesh']}: "
        f"C={t['compute_s']*1e3:.2f}ms M={t['memory_s']*1e3:.2f}ms "
        f"X={t['collective_s']*1e3:.2f}ms -> {t['bottleneck']}"
    )
