"""While-loop-aware collective-byte accounting from post-SPMD HLO text.

Collectives that SPMD partitioning places inside a scanned layer body appear
once in the HLO but execute once per trip.  This parser splits the module
into computations, reads each while op's trip count from its
backend_config ("known_trip_count") — falling back to the loop-condition
constant — and multiplies nested collective bytes accordingly.
"""

from __future__ import annotations

import re
from typing import Dict

from repro.analysis.roofline import _COLL_OPS, _shape_bytes

_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?calls=%?([\w\.\-]+)")


def _comp_name(header: str) -> str:
    s = header.strip()
    if s.startswith("ENTRY"):
        s = s[len("ENTRY"):].strip()
    s = s.lstrip("%")
    for i, ch in enumerate(s):
        if ch in " (":
            return s[:i]
    return s


def _split_computations(hlo: str):
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if stripped.endswith("{") and (
            stripped.lstrip().startswith("%") or stripped.lstrip().startswith("ENTRY")
        ):
            cur = _comp_name(stripped)
            comps[cur] = []
            if stripped.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if stripped.strip() == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps, entry


def _line_collective(line: str):
    for op in _COLL_OPS:
        if f" {op}(" in line or f" {op}-start(" in line:
            if f"{op}-done" in line:
                return None
            eq = line.find("=")
            if eq < 0:
                return None
            call = line.find(op, eq)
            result_part = line[eq + 1 : call]
            paren = line.find("(", call)
            operand_part = line[paren:].split("replica_groups")[0]
            return op, _shape_bytes(operand_part), _shape_bytes(result_part)
    return None


def _zero():
    return {op: {"count": 0, "operand_bytes": 0, "result_bytes": 0} for op in _COLL_OPS}


def collective_stats(hlo: str) -> Dict[str, dict]:
    comps, entry = _split_computations(hlo)

    direct: Dict[str, dict] = {}
    whiles: Dict[str, list] = {}  # comp -> [(body_name, trips)]
    for name, lines in comps.items():
        stats = _zero()
        wrefs = []
        for line in lines:
            got = _line_collective(line)
            if got:
                op, ob, rb = got
                stats[op]["count"] += 1
                stats[op]["operand_bytes"] += ob
                stats[op]["result_bytes"] += rb
            if " while(" in line:
                wm = _WHILE_RE.search(line)
                if wm:
                    cond_name, body_name = wm.group(1), wm.group(2)
                    tm = _TRIP_RE.search(line)
                    if tm:
                        trips = int(tm.group(1))
                    else:  # fall back to the max constant in the condition
                        trips = 1
                        for cl in comps.get(cond_name, []):
                            for cm in _CONST_RE.finditer(cl):
                                trips = max(trips, int(cm.group(1)))
                    wrefs.append((body_name, trips))
        direct[name] = stats
        whiles[name] = wrefs

    def total_for(name, depth=0) -> Dict[str, dict]:
        if depth > 16 or name not in direct:
            return _zero()
        acc = {op: dict(direct[name][op]) for op in _COLL_OPS}
        for body_name, trips in whiles.get(name, []):
            inner = total_for(body_name, depth + 1)
            for op in _COLL_OPS:
                for k in ("count", "operand_bytes", "result_bytes"):
                    acc[op][k] += inner[op][k] * trips
        return acc

    if entry is None:
        from repro.analysis.roofline import parse_collectives

        return parse_collectives(hlo)
    return total_for(entry)


_META_RE = re.compile(r'op_name="([^"]+)"')


def collective_sites(hlo: str, top: int = 15):
    """Attribute collective result-bytes to source op_names (metadata), with
    while-trip multiplication — the profiler view for §Perf hillclimbing."""
    comps, entry = _split_computations(hlo)

    sites: Dict[str, dict] = {}  # comp -> list[(op, bytes, op_name)]
    whiles: Dict[str, list] = {}
    per_comp: Dict[str, list] = {}
    for name, lines in comps.items():
        rows = []
        wrefs = []
        for line in lines:
            got = _line_collective(line)
            if got:
                op, _, rb = got
                mm = _META_RE.search(line)
                rows.append((op, rb, mm.group(1) if mm else "?"))
            if " while(" in line:
                wm = _WHILE_RE.search(line)
                if wm:
                    tm = _TRIP_RE.search(line)
                    trips = int(tm.group(1)) if tm else 1
                    wrefs.append((wm.group(2), trips))
        per_comp[name] = rows
        whiles[name] = wrefs

    agg: Dict[str, dict] = {}

    def walk(name, mult, depth=0):
        if depth > 16 or name not in per_comp:
            return
        for op, rb, op_name in per_comp[name]:
            key = f"{op} @ {op_name}"
            e = agg.setdefault(key, {"bytes": 0, "count": 0})
            e["bytes"] += rb * mult
            e["count"] += mult
        for body, trips in whiles.get(name, []):
            walk(body, mult * trips, depth + 1)

    if entry is not None:
        walk(entry, 1)
    ranked = sorted(agg.items(), key=lambda kv: -kv[1]["bytes"])[:top]
    return [
        {"site": k, "bytes": v["bytes"], "count": v["count"]} for k, v in ranked
    ]
