"""Host-side relaxed fingerprint LUT for k-mismatch candidate gating.

The exact engine gates EPSMb verification with a 2^kbits LUT of the window
fingerprints the P patterns can present (core/engine.py).  A text window that
matches a pattern under <= k byte substitutions presents a *different*
fingerprint, so the exact LUT would reject true fuzzy occurrences.  The fix
is precomputing, on host, the set of all fingerprints *reachable* from each
pattern under <= k substitutions in the anchor window, and registering every
one of them (DESIGN.md §8).

Why that expansion is cheap and bounded: the window fingerprint is

    fp(v) = ((v * MULT) mod 2^32) >> (32 - kbits),
    v     = sum_i word_i * salt_i  (mod 2^32),

and v is LINEAR in the window bytes — byte j contributes
``byte * coef_j mod 2^32`` where coef_j folds the per-word salt and the
byte's lane shift over every packed word covering position j (bytes under
the overlapping final word are covered twice; coef_j sums both).  So
substituting byte j from b to b' moves v by exactly ``(b' - b) * coef_j``,
and the <= k-reachable v-set is

    { v0 + sum over a <= k chosen positions of a nonzero delta } ,

of size bounded by C(w, k) * 255^k for window width w — enumerable by pure
numpy broadcasting, no text involved.  For k=1 that is w*255 entries
(~1.6% of the 2^17 table for m=8: the gate still prunes hard); for k=2 the
set approaches table saturation, the gate stops paying, and we return None
so the engine runs its dense counting path instead.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.engine import _FP_MULT, _WORD_SALTS, _word_offsets
from repro.core.packing import PACK

# Expansion is skipped (-> dense path, still exact w.r.t. the k-mismatch
# semantics) when the enumerated set could fill more than this fraction of
# the table: a saturated gate admits every block and only adds overhead.
DENSITY_MAX = 1 / 8
# Hard cap on enumerated v-values per plan (all patterns together): keeps
# plan compilation bounded even for large P * C(w,2) * 255^2 requests.
EXPAND_CAP = 8_000_000


def byte_coefs(m: int) -> Optional[np.ndarray]:
    """uint32 (m,) per-byte linear coefficients of the window fingerprint,
    or None when m needs more packed words than there are salts."""
    offsets = _word_offsets(m)
    if m < PACK or len(offsets) > len(_WORD_SALTS):
        return None
    coef = np.zeros(m, np.uint64)
    for i, o in enumerate(offsets):
        for b in range(PACK):
            coef[o + b] += (np.uint64(_WORD_SALTS[i]) << np.uint64(8 * b))
    return (coef & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def _fp_of(v: np.ndarray, kbits: int) -> np.ndarray:
    return ((v * _FP_MULT) >> np.uint32(32 - kbits)).astype(np.int64)


def expansion_count(m: int, k: int) -> int:
    """Number of enumerated v-values for one pattern window (k' = 0..k)."""
    total = 1
    if k >= 1:
        total += m * 255
    if k >= 2:
        total += (m * (m - 1) // 2) * 255 * 255
    return total


def relaxed_window_lut(
    pats: np.ndarray, *, kbits: int, k: int
) -> Optional[np.ndarray]:
    """(2^kbits,) bool LUT of every fingerprint reachable from any of the
    (P, m) patterns under <= k substitutions, or None when the gate would
    not pay (k > 2, window too wide for the salts, or table saturation)."""
    P, m = pats.shape
    if k > 2:
        return None
    coef = byte_coefs(m)
    if coef is None:
        return None
    cnt = P * expansion_count(m, k)
    if cnt > EXPAND_CAP:
        return None
    # balls-into-bins density estimate: cnt values into 2^kbits buckets
    # saturate the table long before cnt == 2^kbits; skip eagerly.
    table = 1 << kbits
    est_density = 1.0 - np.exp(-cnt / table)
    if est_density > DENSITY_MAX:
        return None

    lut = np.zeros(table, np.bool_)
    with np.errstate(over="ignore"):
        for p in range(P):
            pat = pats[p].astype(np.uint32)
            v0 = np.uint32(
                (pat.astype(np.uint64) * coef.astype(np.uint64)).sum()
                & np.uint64(0xFFFFFFFF)
            )
            lut[_fp_of(np.asarray([v0], np.uint32), kbits)] = True
            if k < 1:
                continue
            # per-position nonzero deltas: (m, 255) uint32
            vals = np.arange(256, dtype=np.uint32)
            dmat = (vals[None, :] - pat[:, None]) * coef[:, None]
            deltas = [dmat[j][vals != pat[j]] for j in range(m)]
            d1 = np.concatenate(deltas)
            lut[_fp_of(v0 + d1, kbits)] = True
            if k < 2:
                continue
            for j1 in range(m):  # chunked over the first position: O(m*255^2)
                for j2 in range(j1 + 1, m):
                    v = v0 + deltas[j1][:, None] + deltas[j2][None, :]
                    lut[_fp_of(v.reshape(-1), kbits)] = True
    if lut.sum() > DENSITY_MAX * table:
        return None
    return lut
