"""repro.approx — packed k-mismatch approximate matching (DESIGN.md §8).

Extends the repo's exact packed-matching substrate to Hamming-distance
matching: a position i matches pattern p under budget k iff the m-byte
window at i differs from p in at most k bytes.  Engine-integrated — the
canonical entry points are ``engine.compile_patterns(..., k=...)`` plus
``engine.match_many / count_many(..., k=...)``; this module adds the
building blocks (packed counting filter, relaxed fingerprint LUT) and
single-pattern conveniences mirroring ``epsm.find`` / ``epsm.count``.
"""

from __future__ import annotations

import numpy as np

import jax

from repro.approx.counting import (  # noqa: F401
    APPROX_CAND_BLOCK,
    count_group_approx,
    match_group_approx,
    mismatch_counts,
)
from repro.approx.relaxed import relaxed_window_lut  # noqa: F401
from repro.core import engine
from repro.core.packing import as_u8


def find_kmismatch(text, pattern, k: int):
    """bool[n] k-mismatch match-start mask for one (text, pattern) pair."""
    plans = engine.compile_patterns_cached([pattern], k=int(k))
    idx = engine.build_index(as_u8(text))
    return engine.match_many_jit(idx, plans, k=int(k))[0, 0]


def count_kmismatch(text, pattern, k: int):
    """Scalar int32 number of k-mismatch occurrences."""
    plans = engine.compile_patterns_cached([pattern], k=int(k))
    idx = engine.build_index(as_u8(text))
    return engine.count_many_jit(idx, plans, k=int(k))[0, 0]


def kmismatch_naive(text, pattern, k: int) -> np.ndarray:
    """Vectorized-numpy oracle: bool[n] mask, the test/bench reference."""
    t = np.asarray(jax.device_get(as_u8(text)))
    p = np.asarray(jax.device_get(as_u8(pattern)))
    n, m = t.shape[0], p.shape[0]
    if n < m:
        return np.zeros(n, bool)
    mm = np.zeros(n - m + 1, np.int32)
    for j in range(m):
        mm += t[j : j + n - m + 1] != p[j]
    out = np.zeros(n, bool)
    out[: n - m + 1] = mm <= k
    return out
