"""Packed k-mismatch counting filter + the engine's approximate matchers.

Per-position mismatch counting reuses the exact path's packed substrate
(DESIGN.md §8): the TextIndex's u32 4-gram view XOR'd against a pattern's
packed anchor words yields agreeing bytes as zero bytes of the result, so
one 32-bit lane op counts 4 byte agreements (count_zero_bytes_u32 — the
vectorized popcount-style sum of Giaquinta/Grabowski/Fredriksson's
symbol-agreement reduction, arXiv:1211.5433).  Only the strided words are
used (the overlapping final anchor word would double-count its bytes); the
m % 4 tail is counted byte-wise.

Two count paths, mirroring the exact engine:

  * dense — (B, P, n) mismatch accumulation, always exact for any k; the
    fallback and the small-input / saturated-gate path;
  * sparse — the relaxed fingerprint LUT (repro.approx.relaxed) gates
    candidate blocks before verification, exactly the exact engine's
    compact-then-verify shape but at APPROX_CAND_BLOCK granularity: the
    relaxed LUT is ~2 orders of magnitude denser than the exact union LUT,
    so the exact path's 32-wide blocks would light up ~40% of the text
    while 8-wide blocks stay ~12% at k=1 density.

Soundness of the gate never depends on the density heuristics: a true
<= k-mismatch occurrence's window fingerprint is in the relaxed set by
construction, and candidate overflow falls back to the dense branch via
lax.cond, exactly like the exact engine.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from typing import Optional

from repro.core.engine import (
    FingerprintBank,
    PatternPlan,
    TextIndex,
    _gather_candidate_rows,
    _valid_starts,
)
from repro.core.packing import PACK, count_zero_bytes_u32, shift_left

# Candidate-block granularity of the sparse k-mismatch path (see module
# docstring for why it is narrower than the exact engine's CAND_BLOCK).
APPROX_CAND_BLOCK = 8
# Sparse path only when the expected candidate-block fraction stays below
# this; above it the gather + fixed-budget nonzero can't beat dense.
BLOCK_FRAC_MAX = 0.25


def _n_strided(m: int) -> int:
    """Packed words usable for counting: full non-overlapping 4-grams."""
    return m // PACK


def mismatch_counts(index: TextIndex, plan: PatternPlan) -> jnp.ndarray:
    """int32 (B, P, n) — Hamming distance between the m-byte window at every
    text position and every pattern (garbage in the <m tail; callers mask
    with _valid_starts).  Packed: m // 4 lane ops + m % 4 byte ops."""
    t, w = index.text, index.packed
    P, m = plan.patterns.shape
    B, n = t.shape
    mm = jnp.zeros((B, P, n), jnp.int32)
    nw = _n_strided(m)
    for i in range(nw):
        x = shift_left(w, PACK * i)[:, None, :] ^ plan.anchors[None, :, i, None]
        mm = mm + (PACK - count_zero_bytes_u32(x))
    for j in range(nw * PACK, m):
        mm = mm + (
            shift_left(t, j)[:, None, :] != plan.patterns[None, :, j, None]
        ).astype(jnp.int32)
    return mm


def match_group_approx(
    index: TextIndex, plan: PatternPlan, k: int, end_min=None
) -> jnp.ndarray:
    """bool (B, P, n) k-mismatch match-start mask.  Dense by design: for full
    masks the output write dominates (same argument as the exact engine's
    _match_group_b), so the counting filter runs at every position.
    ``end_min`` is the streaming seam gate (engine.match_many)."""
    ok = mismatch_counts(index, plan) <= k
    return ok & _valid_starts(index, plan.m, end_min)[:, None, :]


def _dense_count_approx(
    index: TextIndex, plan: PatternPlan, k: int, end_min=None
) -> jnp.ndarray:
    return match_group_approx(index, plan, k, end_min).sum(-1, dtype=jnp.int32)


def _approx_candidates(
    index: TextIndex,
    plan: PatternPlan,
    bank: Optional[FingerprintBank] = None,
    end_min=None,
):
    """Relaxed-LUT candidate blocks: one O(n) window fingerprint + probe
    (independent of P and k), compacted to APPROX_CAND_BLOCK granularity.
    The fingerprint itself is a shared-prefix read from the FingerprintBank
    — exact and approx plans of any length split one pass over `packed`."""
    B, n = index.text.shape
    if bank is None:
        bank = FingerprintBank(index.packed)
    h = bank.window_fp(plan.m, plan.kbits)
    cand = plan.relaxed_lut[h] & _valid_starts(index, plan.m, end_min)
    C = APPROX_CAND_BLOCK
    nblk = -(-n // C)
    pad = nblk * C - n
    blk_any = jnp.pad(cand, ((0, 0), (0, pad))).reshape(B, nblk, C).any(-1)
    # 2x the random-text expectation plus per-row slack covers fingerprint
    # collisions and true fuzzy matches; overflow falls back to dense.
    exp_blocks = int(B * nblk * _block_frac(plan))
    budget = int(min(B * nblk, max(1024, 2 * exp_blocks + 8 * B)))
    return blk_any, budget, nblk


def _block_frac(plan: PatternPlan) -> float:
    """Expected candidate-block fraction on random text (host-side)."""
    density = plan.relaxed_bits / (1 << plan.kbits)
    return 1.0 - (1.0 - density) ** APPROX_CAND_BLOCK


def _approx_verify_counts(
    index: TextIndex, plan: PatternPlan, k: int, blk_any, budget, nblk,
    end_min=None,
) -> jnp.ndarray:
    """Gather candidate blocks, count mismatches at all C positions x P
    patterns on the packed gathered rows, scatter-add per-text counts."""
    B = index.batch
    P, m = plan.patterns.shape
    C = APPROX_CAND_BLOCK
    rows_packed, bvec, bstart, live = _gather_candidate_rows(
        index, m, blk_any, budget, nblk, cblock=C
    )
    nb = rows_packed.shape[0]
    mm = jnp.zeros((nb, C, P), jnp.int32)
    nw = _n_strided(m)
    for i in range(nw):
        o = PACK * i
        x = rows_packed[:, o : o + C, None] ^ plan.anchors[None, None, :, i]
        mm = mm + (PACK - count_zero_bytes_u32(x))
    for j in range(nw * PACK, m):
        # byte at gathered position q is the low byte of its packed word
        byte = rows_packed[:, j : j + C] & jnp.uint32(0xFF)
        mm = mm + (byte[:, :, None] != plan.patterns[None, None, :, j]).astype(
            jnp.int32
        )
    starts = bstart[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    in_row = starts <= (index.lengths[bvec][:, None] - m)
    if end_min is not None:
        in_row = in_row & (
            starts + (m - 1) >= jnp.asarray(end_min, jnp.int32)
        )
    ok = (mm <= k) & (in_row & live[:, None])[:, :, None]
    sums = ok.sum(axis=1, dtype=jnp.int32)  # (nb, P)
    counts = jnp.zeros((B, P), jnp.int32)
    return counts.at[bvec].add(sums, mode="drop")


def count_group_approx(
    index: TextIndex,
    plan: PatternPlan,
    k: int,
    bank: Optional[FingerprintBank] = None,
    end_min=None,
) -> jnp.ndarray:
    """int32 (B, P) k-mismatch occurrence counts: relaxed-LUT sparse path
    when the plan carries a usable gate, dense counting otherwise."""
    B, n = index.text.shape
    C = APPROX_CAND_BLOCK
    # Same shape as the exact engine's count heuristic, re-measured for the
    # k-mismatch costs: dense packed counting is ~1 lane-op per window word
    # (m=8, k=1, 1 MB: 2.0ms vs 9.2ms for the gated path at P=1 — the fixed
    # nonzero over n/C blocks is the sparse floor), so the gate only pays
    # once the dense O(B*n*P) counting dwarfs that floor AND the union
    # relaxed LUT is still sparse enough to prune blocks.
    gated = (
        plan.relaxed_lut is not None
        and k <= plan.k  # reachable set for plan.k covers any smaller budget
        and n >= 4 * C
        and plan.n_patterns >= 4
        and B * n * plan.n_patterns >= 8_000_000
        and _block_frac(plan) <= BLOCK_FRAC_MAX
    )
    if not gated:
        return _dense_count_approx(index, plan, k, end_min)
    blk_any, budget, nblk = _approx_candidates(index, plan, bank, end_min)
    return lax.cond(
        blk_any.sum(dtype=jnp.int32) <= budget,
        lambda _: _approx_verify_counts(
            index, plan, k, blk_any, budget, nblk, end_min
        ),
        lambda _: _dense_count_approx(index, plan, k, end_min),
        None,
    )
