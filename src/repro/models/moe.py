"""Mixture-of-Experts FFN (GShard/Switch-style capacity dispatch, top-k).

Tokens are processed in fixed-size groups; within a group a (g, E, C)
dispatch one-hot routes each token to its top-k experts (capacity-dropped,
residual passes through for dropped tokens).  The dispatch/combine einsums
are the standard TPU formulation — they shard cleanly with experts on the
'model' axis (EP) or d_ff on the 'model' axis (expert-TP) depending on
divisibility (see dist/sharding.py).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import KeyGen, scaled_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    group_size: int = 4096  # tokens per dispatch group


def capacity(cfg: MoEConfig, group: int) -> int:
    c = int(group * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to multiple of 4


def moe_params_init(kg: KeyGen, d_model: int, d_ff: int, cfg: MoEConfig, dtype):
    E = cfg.num_experts
    return {
        "router": scaled_init(d_model)(kg(), (d_model, E), jnp.float32),
        "w1": scaled_init(d_model)(kg(), (E, d_model, d_ff), dtype),
        "w3": scaled_init(d_model)(kg(), (E, d_model, d_ff), dtype),
        "w2": scaled_init(d_ff)(kg(), (E, d_ff, d_model), dtype),
    }


def _route(logits: jnp.ndarray, cfg: MoEConfig, cap: int):
    """Build dispatch (g, E, C) and combine (g, E, C) tensors for one group.

    GShard-style top-k with capacity: each routing round assigns every token
    its next-best expert; a token's slot within an expert's capacity buffer is
    its prefix count (tokens assigned to that expert earlier in the group or
    in earlier rounds).  Tokens past capacity are dropped (residual carries
    them).  Combine gates are the selected softmax probs renormalized over
    the token's selected experts (pre-drop mass), as in Mixtral/GShard.
    """
    g, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # (g, E)
    dispatch = jnp.zeros((g, E, cap), jnp.float32)
    combine = jnp.zeros((g, E, cap), jnp.float32)
    masked = probs
    prev_count = jnp.zeros((E,), jnp.float32)  # tokens already in each buffer
    gate_total = jnp.zeros((g,), jnp.float32)  # selected prob mass (pre-drop)
    for _ in range(cfg.top_k):
        idx = jnp.argmax(masked, axis=-1)  # (g,)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (g, E)
        within = jnp.cumsum(onehot, axis=0) - onehot  # earlier tokens this round
        pos_e = within + prev_count[None, :]  # (g, E)
        pos = (pos_e * onehot).sum(axis=-1).astype(jnp.int32)  # (g,)
        keep = (pos < cap).astype(jnp.float32)
        gate = (probs * onehot).sum(axis=-1)  # (g,)
        gate_total = gate_total + gate
        pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)  # (g, C)
        sel = onehot * keep[:, None]
        dispatch = dispatch + sel[:, :, None] * pos_oh[:, None, :]
        combine = combine + ((gate * keep)[:, None] * onehot)[:, :, None] * pos_oh[:, None, :]
        prev_count = prev_count + onehot.sum(axis=0)
        masked = masked * (1.0 - onehot)  # don't re-pick the same expert
    combine = combine / jnp.maximum(gate_total, 1e-9)[:, None, None]
    return dispatch, combine, probs


def _aux_loss(probs: jnp.ndarray, dispatch: jnp.ndarray, E: int) -> jnp.ndarray:
    """Switch-style load-balancing loss for one group."""
    # fraction of tokens dispatched to each expert (first-choice proxy)
    me = probs.mean(axis=0)  # (E,)
    ce = dispatch.sum(axis=2).mean(axis=0)  # (E,) average assignment
    return E * jnp.sum(me * ce)


def moe_ffn(
    x: jnp.ndarray,  # (T, d_model) flattened tokens
    params: dict,
    cfg: MoEConfig,
    act=jax.nn.silu,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (T, d_model), aux_loss scalar)."""
    T, d = x.shape
    g = min(cfg.group_size, T)
    assert T % g == 0, f"tokens {T} not divisible by group {g}"
    ngroups = T // g
    cap = capacity(cfg, g)
    E = cfg.num_experts

    xg = x.reshape(ngroups, g, d)

    def group_fn(xi):
        logits = xi.astype(jnp.float32) @ params["router"]  # (g, E)
        dispatch, combine, probs = _route(logits, cfg, cap)
        xd = jnp.einsum("gec,gd->ecd", dispatch.astype(xi.dtype), xi)  # (E,C,d)
        h = act(jnp.einsum("ecd,edf->ecf", xd, params["w1"])) * jnp.einsum(
            "ecd,edf->ecf", xd, params["w3"]
        )
        ye = jnp.einsum("ecf,efd->ecd", h, params["w2"])  # (E, C, d)
        y = jnp.einsum("gec,ecd->gd", combine.astype(ye.dtype), ye)  # (g, d)
        return y, _aux_loss(probs, dispatch, E)

    if ngroups == 1:
        y, aux = group_fn(xg[0])
        return y.reshape(T, d), aux
    # vmap, NOT lax.map: a scan over groups would serialize the (sharded)
    # group dimension, forcing every shard to process every group and
    # all-reducing each dispatch einsum (measured 2.7TB/chip on
    # grok-1/train_4k — §Perf iteration 3).  vmap keeps the group dim
    # sharded; dispatch/combine tensors are transient per layer.
    ys, auxs = jax.vmap(group_fn)(xg)
    return ys.reshape(T, d), auxs.mean()
