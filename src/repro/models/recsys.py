"""RecSys / ranking models: DIN, DIEN, BST, DCN-v2 (assigned configs).

Structure shared by all four: sparse embedding tables (the hot path; see
models/embedding.py) -> feature interaction (target attention / AUGRU /
transformer block / cross network) -> small MLP tower -> CTR logit.

Batch layouts (built by data/recsys_data.py, shape-specs by configs/):
  DIN/DIEN: {"hist_items": (B,T), "hist_cates": (B,T), "hist_mask": (B,T),
             "target_item": (B,), "target_cate": (B,), "label": (B,)}
  BST:      same with T=20 (target appended as the 21st sequence position)
  DCN-v2:   {"dense": (B,13), "sparse": (B,26), "label": (B,)}

``retrieval_scores`` scores ONE user against C candidates (retrieval_cand
shape) as a batched forward — no loop over candidates.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import (
    KeyGen,
    binary_cross_entropy,
    dtype_of,
    mlp_apply,
    mlp_init,
    normal_init,
    scaled_init,
)
from repro.models.embedding import TableSpec, embedding_lookup, init_table


# ===========================================================================
# Configs
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class RecSysConfig:
    name: str
    kind: str  # "din" | "dien" | "bst" | "dcn"
    embed_dim: int
    seq_len: int = 0
    item_vocab: int = 1_000_000
    cate_vocab: int = 10_000
    # DIN
    attn_mlp: Tuple[int, ...] = (80, 40)
    mlp: Tuple[int, ...] = (200, 80)
    # DIEN
    gru_dim: int = 0
    # BST
    n_heads: int = 8
    n_blocks: int = 1
    # DCN
    n_dense: int = 13
    n_sparse: int = 26
    n_cross_layers: int = 3
    sparse_vocabs: Tuple[int, ...] = ()
    dtype: str = "float32"
    param_dtype: str = "float32"

    @property
    def pair_dim(self) -> int:
        """item+cate embedding concat width for sequence models."""
        return 2 * self.embed_dim


def dcn_default_vocabs(n_sparse: int = 26) -> Tuple[int, ...]:
    """Criteo-like skewed vocab sizes: a few huge fields, a long small tail."""
    vocabs = [10_000_000] * 3 + [1_000_000] * 5 + [100_000] * 8 + [10_000] * 10
    return tuple(vocabs[:n_sparse])


# ===========================================================================
# Shared init pieces
# ===========================================================================

def _seq_tables(kg: KeyGen, cfg: RecSysConfig, pdt):
    return {
        "item_table": init_table(kg(), TableSpec("item", cfg.item_vocab, cfg.embed_dim), pdt),
        "cate_table": init_table(kg(), TableSpec("cate", cfg.cate_vocab, cfg.embed_dim), pdt),
    }


def _pair_embed(params, items, cates):
    """(..., ) ids -> (..., 2*embed_dim) concat of item and category."""
    return jnp.concatenate(
        [embedding_lookup(params["item_table"], items),
         embedding_lookup(params["cate_table"], cates)],
        axis=-1,
    )


# ===========================================================================
# DIN — Deep Interest Network (target attention over behavior sequence)
# ===========================================================================

def din_init(key, cfg: RecSysConfig):
    kg = KeyGen(key)
    pdt = dtype_of(cfg.param_dtype)
    d = cfg.pair_dim
    p = _seq_tables(kg, cfg, pdt)
    p["attn_mlp"] = mlp_init(kg, [4 * d, *cfg.attn_mlp, 1], pdt)
    p["tower"] = mlp_init(kg, [3 * d, *cfg.mlp, 1], pdt)
    return p


def _din_attention(params, hist, tgt, mask):
    """hist (B,T,d), tgt (B,d) -> pooled (B,d). Raw (unnormalized) scores
    as in the paper; masked positions contribute zero."""
    B, T, d = hist.shape
    tgt_b = jnp.broadcast_to(tgt[:, None, :], (B, T, d))
    feats = jnp.concatenate([hist, tgt_b, hist - tgt_b, hist * tgt_b], axis=-1)
    scores = mlp_apply(params["attn_mlp"], feats, act="sigmoid")[..., 0]  # (B,T)
    scores = scores * mask.astype(scores.dtype)
    return jnp.einsum("bt,btd->bd", scores, hist)


def din_logits(params, cfg: RecSysConfig, batch):
    hist = _pair_embed(params, batch["hist_items"], batch["hist_cates"])
    tgt = _pair_embed(params, batch["target_item"], batch["target_cate"])
    pooled = _din_attention(params, hist, tgt, batch["hist_mask"])
    x = jnp.concatenate([pooled, tgt, pooled * tgt], axis=-1)
    return mlp_apply(params["tower"], x, act="sigmoid")[..., 0]


# ===========================================================================
# DIEN — interest evolution: GRU + attentional AUGRU over the sequence
# ===========================================================================

def _gru_init(kg: KeyGen, d_in: int, d_h: int, pdt):
    return {
        "wz": scaled_init(d_in + d_h)(kg(), (d_in + d_h, d_h), pdt),
        "wr": scaled_init(d_in + d_h)(kg(), (d_in + d_h, d_h), pdt),
        "wh": scaled_init(d_in + d_h)(kg(), (d_in + d_h, d_h), pdt),
        "bz": jnp.zeros((d_h,), pdt),
        "br": jnp.zeros((d_h,), pdt),
        "bh": jnp.zeros((d_h,), pdt),
    }


def _gru_cell(p, h, x, att=None):
    """Standard GRU; if att (scalar per example) given, AUGRU gate scaling."""
    xh = jnp.concatenate([x, h], axis=-1)
    z = jax.nn.sigmoid(xh @ p["wz"] + p["bz"])
    r = jax.nn.sigmoid(xh @ p["wr"] + p["br"])
    xrh = jnp.concatenate([x, r * h], axis=-1)
    cand = jnp.tanh(xrh @ p["wh"] + p["bh"])
    if att is not None:
        z = z * att[:, None]  # AUGRU: attention modulates the update gate
    return (1.0 - z) * h + z * cand


def dien_init(key, cfg: RecSysConfig):
    kg = KeyGen(key)
    pdt = dtype_of(cfg.param_dtype)
    d, dh = cfg.pair_dim, cfg.gru_dim
    p = _seq_tables(kg, cfg, pdt)
    p["gru1"] = _gru_init(kg, d, dh, pdt)
    p["gru2"] = _gru_init(kg, dh, dh, pdt)
    p["att_w"] = scaled_init(dh)(kg(), (dh, d), pdt)  # bilinear attention
    p["tower"] = mlp_init(kg, [d + dh, *cfg.mlp, 1], pdt)
    return p


def dien_logits(params, cfg: RecSysConfig, batch):
    hist = _pair_embed(params, batch["hist_items"], batch["hist_cates"])  # (B,T,d)
    tgt = _pair_embed(params, batch["target_item"], batch["target_cate"])  # (B,d)
    mask = batch["hist_mask"].astype(hist.dtype)
    B, T, d = hist.shape
    dh = cfg.gru_dim

    # interest extraction GRU
    def step1(h, xt):
        x, mk = xt
        h_new = _gru_cell(params["gru1"], h, x)
        h = jnp.where(mk[:, None] > 0, h_new, h)
        return h, h

    h0 = jnp.zeros((B, dh), hist.dtype)
    _, states = lax.scan(step1, h0, (hist.swapaxes(0, 1), mask.swapaxes(0, 1)))
    states = states.swapaxes(0, 1)  # (B, T, dh)

    # attention of each interest state w.r.t. the target (bilinear + softmax)
    att_logits = jnp.einsum("bth,hd,bd->bt", states, params["att_w"], tgt)
    att_logits = jnp.where(mask > 0, att_logits, -1e30)
    att = jax.nn.softmax(att_logits, axis=-1)  # (B, T)

    # interest evolution AUGRU
    def step2(h, xt):
        s, a, mk = xt
        h_new = _gru_cell(params["gru2"], h, s, att=a)
        h = jnp.where(mk[:, None] > 0, h_new, h)
        return h, None

    h0 = jnp.zeros((B, dh), hist.dtype)
    h_final, _ = lax.scan(
        step2, h0, (states.swapaxes(0, 1), att.swapaxes(0, 1), mask.swapaxes(0, 1))
    )
    x = jnp.concatenate([tgt, h_final], axis=-1)
    return mlp_apply(params["tower"], x, act="sigmoid")[..., 0]


# ===========================================================================
# BST — Behavior Sequence Transformer
# ===========================================================================

def bst_init(key, cfg: RecSysConfig):
    kg = KeyGen(key)
    pdt = dtype_of(cfg.param_dtype)
    d = cfg.pair_dim  # transformer width = item+cate embed
    p = _seq_tables(kg, cfg, pdt)
    p["pos_table"] = normal_init(kg(), (cfg.seq_len + 1, d), pdt)
    blocks = []
    for _ in range(cfg.n_blocks):
        blocks.append(
            {
                "wq": scaled_init(d)(kg(), (d, d), pdt),
                "wk": scaled_init(d)(kg(), (d, d), pdt),
                "wv": scaled_init(d)(kg(), (d, d), pdt),
                "wo": scaled_init(d)(kg(), (d, d), pdt),
                "ln1": jnp.ones((d,), pdt),
                "ln1_b": jnp.zeros((d,), pdt),
                "ln2": jnp.ones((d,), pdt),
                "ln2_b": jnp.zeros((d,), pdt),
                "ff1": scaled_init(d)(kg(), (d, 4 * d), pdt),
                "ff2": scaled_init(4 * d)(kg(), (4 * d, d), pdt),
            }
        )
    p["blocks"] = blocks
    p["tower"] = mlp_init(kg, [(cfg.seq_len + 1) * d, *cfg.mlp, 1], pdt)
    return p


def _bst_block(blk, x, mask, n_heads, eps=1e-5):
    from repro.models.common import layernorm

    B, T, d = x.shape
    hd = d // n_heads
    xa = layernorm(x, blk["ln1"], blk["ln1_b"], eps)
    q = (xa @ blk["wq"]).reshape(B, T, n_heads, hd)
    k = (xa @ blk["wk"]).reshape(B, T, n_heads, hd)
    v = (xa @ blk["wv"]).reshape(B, T, n_heads, hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    s = jnp.where(mask[:, None, None, :] > 0, s, -1e30)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(B, T, d)
    x = x + o @ blk["wo"]
    xf = layernorm(x, blk["ln2"], blk["ln2_b"], eps)
    x = x + jax.nn.relu(xf @ blk["ff1"]) @ blk["ff2"]
    return x


def bst_logits(params, cfg: RecSysConfig, batch):
    hist = _pair_embed(params, batch["hist_items"], batch["hist_cates"])  # (B,T,d)
    tgt = _pair_embed(params, batch["target_item"], batch["target_cate"])  # (B,d)
    seq = jnp.concatenate([hist, tgt[:, None, :]], axis=1)  # target appended
    B, T1, d = seq.shape
    seq = seq + params["pos_table"][None, :T1, :]
    mask = jnp.concatenate(
        [batch["hist_mask"], jnp.ones((B, 1), batch["hist_mask"].dtype)], axis=1
    )
    x = seq
    for blk in params["blocks"]:
        x = _bst_block(blk, x, mask, cfg.n_heads)
    x = (x * mask[..., None].astype(x.dtype)).reshape(B, T1 * d)
    return mlp_apply(params["tower"], x, act="relu")[..., 0]


# ===========================================================================
# DCN-v2 — deep & cross network (full-rank cross layers, stacked)
# ===========================================================================

def dcn_init(key, cfg: RecSysConfig):
    kg = KeyGen(key)
    pdt = dtype_of(cfg.param_dtype)
    vocabs = cfg.sparse_vocabs or dcn_default_vocabs(cfg.n_sparse)
    tables = [
        init_table(kg(), TableSpec(f"f{i}", v, cfg.embed_dim), pdt)
        for i, v in enumerate(vocabs)
    ]
    x0_dim = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    cross = []
    for _ in range(cfg.n_cross_layers):
        cross.append(
            {
                "w": scaled_init(x0_dim)(kg(), (x0_dim, x0_dim), pdt),
                "b": jnp.zeros((x0_dim,), pdt),
            }
        )
    return {
        "tables": tables,
        "cross": cross,
        "tower": mlp_init(kg, [x0_dim, *cfg.mlp, 1], pdt),
    }


def dcn_logits(params, cfg: RecSysConfig, batch):
    dense = batch["dense"].astype(dtype_of(cfg.dtype))  # (B, 13)
    sparse = batch["sparse"]  # (B, 26) int32
    embs = [
        embedding_lookup(tab, sparse[:, i]) for i, tab in enumerate(params["tables"])
    ]  # 26 x (B, d)
    x0 = jnp.concatenate([dense] + embs, axis=-1)  # (B, 429)
    x = x0
    for layer in params["cross"]:
        x = x0 * (x @ layer["w"] + layer["b"]) + x  # DCN-v2 cross
    return mlp_apply(params["tower"], x, act="relu")[..., 0]


# ===========================================================================
# Dispatch + losses + retrieval
# ===========================================================================

_INITS = {"din": din_init, "dien": dien_init, "bst": bst_init, "dcn": dcn_init}
_LOGITS = {"din": din_logits, "dien": dien_logits, "bst": bst_logits, "dcn": dcn_logits}


def init_params(key, cfg: RecSysConfig):
    return _INITS[cfg.kind](key, cfg)


def param_shapes(cfg: RecSysConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


def logits(params, cfg: RecSysConfig, batch):
    return _LOGITS[cfg.kind](params, cfg, batch)


def train_loss(params, cfg: RecSysConfig, batch):
    lg = logits(params, cfg, batch)
    return binary_cross_entropy(lg, batch["label"]).mean()


def serve_scores(params, cfg: RecSysConfig, batch):
    return jax.nn.sigmoid(logits(params, cfg, batch))


def retrieval_scores(params, cfg: RecSysConfig, user_batch, candidates):
    """Score ONE user against C candidate items as a single batched forward.

    user_batch: the sequence-model fields with B=1 (or dense/sparse for dcn).
    candidates: (C,) item ids (sequence models) or (C, n_sparse) rows (dcn).
    """
    C = candidates.shape[0]
    if cfg.kind == "dcn":
        batch = {
            "dense": jnp.broadcast_to(user_batch["dense"], (C, cfg.n_dense)),
            "sparse": candidates,
        }
        return serve_scores(params, cfg, batch)
    T = cfg.seq_len
    batch = {
        "hist_items": jnp.broadcast_to(user_batch["hist_items"], (C, T)),
        "hist_cates": jnp.broadcast_to(user_batch["hist_cates"], (C, T)),
        "hist_mask": jnp.broadcast_to(user_batch["hist_mask"], (C, T)),
        "target_item": candidates,
        "target_cate": candidates % cfg.cate_vocab,
    }
    return serve_scores(params, cfg, batch)
