"""Sparse embedding substrate for the recsys family.

JAX has no native EmbeddingBag or CSR sparse — lookups are jnp.take gathers
and multi-hot bags are take + jax.ops.segment_sum, built here as first-class
ops (kernel_taxonomy §RecSys).  Two distribution strategies for row-sharded
tables (selected in dist/sharding.py / hillclimbed in EXPERIMENTS.md §Perf):

  * "gspmd"  — tables annotated row-sharded, gathers left to the SPMD
               partitioner (baseline).
  * "psum"   — shard_map manual exchange: every device looks up the ids that
               hash to its rows and psums partial vectors (classic
               model-parallel embedding, all-reduce volume = nnz * dim).

The all-to-all (DLRM-style) exchange is implemented in dist/embedding_exchange
as the §Perf optimized variant.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, normal_init


@dataclasses.dataclass(frozen=True)
class TableSpec:
    name: str
    vocab: int
    dim: int


def init_table(key, spec: TableSpec, dtype=jnp.float32):
    # rows scaled ~ 1/sqrt(dim) as in DLRM
    return normal_init(key, (spec.vocab, spec.dim), dtype, stddev=1.0 / jnp.sqrt(spec.dim))


def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Single-hot lookup: (V, d), (...,) int -> (..., d)."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(
    table: jnp.ndarray,
    ids: jnp.ndarray,  # (nnz,) int32
    segment_ids: jnp.ndarray,  # (nnz,) int32 bag index per id
    num_bags: int,
    combiner: str = "sum",
    weights: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """torch.nn.EmbeddingBag equivalent: gather + segment reduce."""
    v = jnp.take(table, ids, axis=0)  # (nnz, d)
    if weights is not None:
        v = v * weights[:, None]
    if combiner == "sum":
        return jax.ops.segment_sum(v, segment_ids, num_segments=num_bags)
    if combiner == "mean":
        s = jax.ops.segment_sum(v, segment_ids, num_segments=num_bags)
        c = jax.ops.segment_sum(
            jnp.ones((ids.shape[0], 1), v.dtype), segment_ids, num_segments=num_bags
        )
        return s / jnp.maximum(c, 1.0)
    if combiner == "max":
        return jax.ops.segment_max(v, segment_ids, num_segments=num_bags)
    raise ValueError(f"unknown combiner {combiner}")


def hash_bucket(ids: jnp.ndarray, vocab: int) -> jnp.ndarray:
    """Hash arbitrary ids into table rows (quotient-remainder-free variant)."""
    x = ids.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x45D9F3B)
    x = (x ^ (x >> 16)) * jnp.uint32(0x45D9F3B)
    x = x ^ (x >> 16)
    return (x % jnp.uint32(vocab)).astype(jnp.int32)


def masked_mean_pool(emb: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """(B, T, d) x (B, T) -> (B, d)."""
    m = mask.astype(emb.dtype)[..., None]
    return (emb * m).sum(axis=1) / jnp.maximum(m.sum(axis=1), 1.0)
