"""LM-family transformer: llama-style blocks with GQA + RoPE + RMSNorm,
optional MoE FFN (phi3.5-moe, grok-1), scan-over-layers with remat.

Three entry points, one per assigned LM shape kind:
  * ``train_loss``    — (B, S) tokens -> scalar CE loss      (train_4k)
  * ``prefill``       — (B, S) tokens -> last logits + KV cache (prefill_32k)
  * ``decode_step``   — one token + KV cache -> logits + cache  (decode_32k,
                        long_500k; linear in S, flash-decoding shards S)

Layers are stacked along axis 0 and scanned (jax.lax.scan) so HLO size is
independent of depth; each block is rematerialized (jax.checkpoint) so peak
activation memory is one layer deep.  Activation sharding constraints are
injected via a `constrain(x, name)` callback supplied by dist/sharding.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.attention import (apply_rope, decode_attention,
                                     decode_attention_q8, flash_attention)
from repro.models.common import KeyGen, dtype_of, normal_init, rmsnorm, scaled_init
from repro.models.moe import MoEConfig, moe_ffn, moe_params_init


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    moe_experts: int = 0  # 0 => dense FFN
    moe_top_k: int = 2
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    q_chunk: int = 1024
    kv_chunk: int = 1024
    ce_chunk: int = 512
    moe_group: int = 4096
    aux_loss_coef: float = 0.01
    remat: bool = True
    tie_embeddings: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def moe_cfg(self) -> MoEConfig:
        return MoEConfig(
            num_experts=self.moe_experts,
            top_k=self.moe_top_k,
            group_size=self.moe_group,
        )

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, V = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.is_moe:
            ffn = self.moe_experts * 3 * d * f + d * self.moe_experts
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        emb = V * d if self.tie_embeddings else V * d * 2
        return emb + self.n_layers * per_layer + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f, V = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        ffn = self.moe_top_k * 3 * d * f + d * self.moe_experts
        per_layer = attn + ffn + 2 * d
        emb = V * d if self.tie_embeddings else V * d * 2
        return emb + self.n_layers * per_layer + d


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: LMConfig):
    kg = KeyGen(key)
    d, hd = cfg.d_model, cfg.head_dim
    pdt = dtype_of(cfg.param_dtype)
    p = {
        "ln1": jnp.ones((d,), pdt),
        "ln2": jnp.ones((d,), pdt),
        "wq": scaled_init(d)(kg(), (d, cfg.n_heads * hd), pdt),
        "wk": scaled_init(d)(kg(), (d, cfg.n_kv_heads * hd), pdt),
        "wv": scaled_init(d)(kg(), (d, cfg.n_kv_heads * hd), pdt),
        "wo": scaled_init(cfg.n_heads * hd)(kg(), (cfg.n_heads * hd, d), pdt),
    }
    if cfg.is_moe:
        p["moe"] = moe_params_init(kg, d, cfg.d_ff, cfg.moe_cfg, pdt)
    else:
        p["mlp"] = {
            "w1": scaled_init(d)(kg(), (d, cfg.d_ff), pdt),
            "w3": scaled_init(d)(kg(), (d, cfg.d_ff), pdt),
            "w2": scaled_init(cfg.d_ff)(kg(), (cfg.d_ff, d), pdt),
        }
    return p


def init_params(key, cfg: LMConfig):
    kg = KeyGen(key)
    pdt = dtype_of(cfg.param_dtype)
    layer_keys = jax.random.split(kg(), cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    p = {
        "embed": normal_init(kg(), (cfg.vocab, cfg.d_model), pdt),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), pdt),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = normal_init(kg(), (cfg.d_model, cfg.vocab), pdt)
    return p


def unembed_matrix(params, cfg: LMConfig):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def param_shapes(cfg: LMConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _no_constrain(x, name):
    del name
    return x


def _attn_block(h, lp, cfg: LMConfig, positions, constrain):
    B, S, d = h.shape
    hd = cfg.head_dim
    x = rmsnorm(h, lp["ln1"], cfg.norm_eps)
    q = (x @ lp["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ lp["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ lp["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "qkv")
    # gather K/V across the sequence shards ONCE per layer: the kv-chunk
    # scan dynamic-slices the length dim, and slicing a sharded dim forces
    # an all-gather PER CHUNK otherwise (§Perf iteration 1)
    k = constrain(k, "kv_attn")
    v = constrain(v, "kv_attn")
    o = flash_attention(
        q, k, v, causal=True, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk
    )
    return h + (o.reshape(B, S, -1) @ lp["wo"]), (k, v)


def _ffn_block(h, lp, cfg: LMConfig, constrain):
    B, S, d = h.shape
    x = rmsnorm(h, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        x = constrain(x, "moe_in")  # gather seq: MoE groups are token-batched
        y, aux = moe_ffn(x.reshape(B * S, d), lp["moe"], cfg.moe_cfg)
        y = y.reshape(B, S, d)
    else:
        hmid = jax.nn.silu(x @ lp["mlp"]["w1"]) * (x @ lp["mlp"]["w3"])
        hmid = constrain(hmid, "ffn_hidden")
        y = hmid @ lp["mlp"]["w2"]
        aux = jnp.zeros((), jnp.float32)
    return h + y, aux


def _block(h, lp, cfg: LMConfig, positions, constrain):
    h, _ = _attn_block(h, lp, cfg, positions, constrain)
    h, aux = _ffn_block(h, lp, cfg, constrain)
    h = constrain(h, "residual")
    return h, aux


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def backbone(params, cfg: LMConfig, tokens, constrain=_no_constrain):
    """(B, S) int32 -> final hidden states (B, S, d)."""
    dt = dtype_of(cfg.dtype)
    h = params["embed"][tokens].astype(dt)
    h = constrain(h, "residual")
    S = tokens.shape[1]
    positions = jnp.arange(S)

    def body(h, lp):
        return _block(h, lp, cfg, positions, constrain)

    if cfg.remat:
        body = jax.checkpoint(body)
    h, auxs = lax.scan(body, h, params["layers"])
    h = rmsnorm(h, params["ln_f"], cfg.norm_eps)
    return h, auxs.mean()


def chunked_ce_loss(h, unembed, targets, chunk: int, constrain=_no_constrain):
    """Cross-entropy without materializing (B, S, V) logits at once."""
    B, S, d = h.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, d).swapaxes(0, 1)  # (nc, B, c, d)
    tc = targets.reshape(B, nc, chunk).swapaxes(0, 1)

    def body(tot, inp):
        hh, tt = inp
        logits = (hh @ unembed).astype(jnp.float32)  # (B, c, V)
        logits = constrain(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        # picked logit via mask+sum: shards cleanly over a vocab-sharded
        # logits tensor (take_along_axis forces an involuntary full
        # rematerialization under GSPMD — §Perf iteration 2)
        V = logits.shape[-1]
        vmask = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2) == tt[..., None]
        picked = jnp.sum(jnp.where(vmask, logits, 0.0), axis=-1)
        return tot + (lse - picked).sum(), None

    tot, _ = lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc))
    return tot / (B * S)


def train_loss(params, cfg: LMConfig, batch, constrain=_no_constrain):
    """batch = {"tokens": (B, S), "targets": (B, S)} -> scalar loss."""
    h, aux = backbone(params, cfg, batch["tokens"], constrain)
    loss = chunked_ce_loss(h, unembed_matrix(params, cfg), batch["targets"], cfg.ce_chunk, constrain)
    return loss + cfg.aux_loss_coef * aux


def prefill(params, cfg: LMConfig, tokens, constrain=_no_constrain):
    """(B, S) -> (last-token logits (B, V), kcache, vcache (L, B, S, KV, hd))."""
    dt = dtype_of(cfg.dtype)
    h = params["embed"][tokens].astype(dt)
    h = constrain(h, "residual")
    S = tokens.shape[1]
    positions = jnp.arange(S)

    def body(h, lp):
        h, (k, v) = _attn_block(h, lp, cfg, positions, constrain)
        h, _ = _ffn_block(h, lp, cfg, constrain)
        h = constrain(h, "residual")
        return h, (constrain(k, "kv_cache"), constrain(v, "kv_cache"))

    if cfg.remat:
        body = jax.checkpoint(body)
    h, (kc, vc) = lax.scan(body, h, params["layers"])
    h = rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = (h[:, -1, :] @ unembed_matrix(params, cfg)).astype(jnp.float32)
    return logits, kc, vc


def decode_step(params, cfg: LMConfig, token, pos, kcache, vcache, constrain=_no_constrain):
    """One decoding step.

    token:  (B, 1) int32 — the newest token.
    pos:    scalar int32 — its position (cache has `pos` valid entries).
    kcache/vcache: (L, B, S_max, KV, hd).
    Returns (logits (B, V), new kcache, new vcache).
    """
    dt = dtype_of(cfg.dtype)
    B = token.shape[0]
    hd = cfg.head_dim
    h = params["embed"][token].astype(dt)  # (B, 1, d)
    positions = jnp.full((B, 1), pos, jnp.int32)

    def body(h, xs):
        lp, kc, vc = xs
        x = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q = (x @ lp["wq"]).reshape(B, 1, cfg.n_heads, hd)
        k = (x @ lp["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
        v = (x @ lp["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
        vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
        kc = constrain(kc, "kv_cache_l")
        vc = constrain(vc, "kv_cache_l")
        o = decode_attention(q, kc, vc, pos + 1)
        h = h + (o.reshape(B, 1, -1) @ lp["wo"])
        h, _ = _ffn_block(h, lp, cfg, constrain)
        return h, (kc, vc)

    h, (kc, vc) = lax.scan(body, h, (params["layers"], kcache, vcache))
    h = rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = (h[:, 0, :] @ unembed_matrix(params, cfg)).astype(jnp.float32)
    return logits, kc, vc


def make_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or dtype_of(cfg.dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return jnp.zeros(shape, dt), jnp.zeros(shape, dt)


# ---------------------------------------------------------------------------
# int8-quantized KV cache (long-context decode is KV-read memory-bound —
# EXPERIMENTS.md §Roofline; per-(position, kv-head) scales, ~1.94x smaller)
# ---------------------------------------------------------------------------

def quantize_kv(x: jnp.ndarray):
    """x (..., hd) -> (int8 values, fp32 scale over the last dim)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def make_cache_q8(cfg: LMConfig, batch: int, max_len: int):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    sshape = shape[:-1]
    zero = lambda: {"q": jnp.zeros(shape, jnp.int8),
                    "scale": jnp.zeros(sshape, jnp.float32)}
    return zero(), zero()


def quantize_cache(kc: jnp.ndarray, vc: jnp.ndarray):
    kq, ks = quantize_kv(kc)
    vq, vs = quantize_kv(vc)
    return {"q": kq, "scale": ks}, {"q": vq, "scale": vs}


def decode_step_q8(params, cfg: LMConfig, token, pos, kcache, vcache,
                   constrain=_no_constrain):
    """decode_step with int8 KV caches: cache dicts {"q": int8, "scale": f32}.

    New K/V entries are quantized before insertion; attention dequantizes on
    read (per-position scales — KIVI/KVQuant-style, per-token granularity).
    """
    dt = dtype_of(cfg.dtype)
    B = token.shape[0]
    hd = cfg.head_dim
    h = params["embed"][token].astype(dt)
    positions = jnp.full((B, 1), pos, jnp.int32)

    def body(h, xs):
        lp, kc, vc = xs
        x = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q = (x @ lp["wq"]).reshape(B, 1, cfg.n_heads, hd)
        k = (x @ lp["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
        v = (x @ lp["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        kc = {
            "q": lax.dynamic_update_slice_in_dim(kc["q"], kq, pos, axis=1),
            "scale": lax.dynamic_update_slice_in_dim(kc["scale"], ks, pos, axis=1),
        }
        vc = {
            "q": lax.dynamic_update_slice_in_dim(vc["q"], vq, pos, axis=1),
            "scale": lax.dynamic_update_slice_in_dim(vc["scale"], vs, pos, axis=1),
        }
        kc = {"q": constrain(kc["q"], "kv_cache_l"),
              "scale": constrain(kc["scale"], "kv_cache_scale")}
        vc = {"q": constrain(vc["q"], "kv_cache_l"),
              "scale": constrain(vc["scale"], "kv_cache_scale")}
        o = decode_attention_q8(
            q, kc["q"], kc["scale"], vc["q"], vc["scale"], pos + 1
        )
        h = h + (o.reshape(B, 1, -1) @ lp["wo"])
        h, _ = _ffn_block(h, lp, cfg, constrain)
        return h, (kc, vc)

    h, (kc, vc) = lax.scan(body, h, (params["layers"], kcache, vcache))
    h = rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = (h[:, 0, :] @ unembed_matrix(params, cfg)).astype(jnp.float32)
    return logits, kc, vc
