"""Attention: GQA with RoPE, chunked (flash-style) prefill/train attention and
single-token decode attention against a KV cache.

The chunked path never materializes the full (Sq, Skv) score matrix: it scans
over KV chunks with online-softmax accumulators, and iterates Q chunks in a
static python loop so causal scheduling can skip fully-masked KV chunks
(triangular schedule — the standard TPU flash-attention shape).

Sharding notes (dist/sharding.py):
  * train/prefill: Q heads shard along 'model' (when n_heads % tp == 0).  GQA
    KV heads (< tp for every assigned arch) are kept replicated and expanded
    to H heads per KV *chunk* via a constant-index gather — the operand is
    replicated and the output is head-sharded, so the expansion is
    communication-free and only costs one tiny chunk-sized buffer.  This is
    the Megatron GQA convention adapted to chunked attention.
  * decode: the KV cache is length-sharded ('model'; flash-decoding); the
    grouped einsum keeps the KVH dim intact (no head sharding needed for a
    single query token) and the softmax reduction over shards becomes a psum.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    if angles.ndim == 2:  # (S, hd/2) -> broadcast over batch
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]  # (B, S, 1, hd/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention
# ---------------------------------------------------------------------------

def _chunk_attn_scan(q, k, v, kv_map, qpos0: int, causal: bool, kv_chunk: int, n_kv: int):
    """Online-softmax scan over the first n_kv KV chunks for one Q chunk.

    q: (B, qc, H, hd); k, v: (B, Skv, KVH, hd); kv_map: (H,) head -> kv head.
    """
    B, qc, H, hd = q.shape

    def body(carry, kv_idx):
        o, m, l = carry
        ks = lax.dynamic_slice_in_dim(k, kv_idx * kv_chunk, kv_chunk, axis=1)
        vs = lax.dynamic_slice_in_dim(v, kv_idx * kv_chunk, kv_chunk, axis=1)
        # GQA expansion: replicated chunk -> head-sharded (B, kc, H, hd);
        # constant-index gather, communication-free under GSPMD.
        ks = jnp.take(ks, kv_map, axis=2)
        vs = jnp.take(vs, kv_map, axis=2)
        s = jnp.einsum("bqhd,bshd->bhqs", q, ks, preferred_element_type=jnp.float32)
        s = s / jnp.sqrt(hd).astype(jnp.float32)
        if causal:
            qpos = qpos0 + jnp.arange(qc)
            kpos = kv_idx * kv_chunk + jnp.arange(kv_chunk)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhqs,bshd->bhqd", p.astype(v.dtype), vs,
                        preferred_element_type=jnp.float32)
        o_new = o * alpha[..., None] + pv
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((B, H, qc, hd), jnp.float32)
    m0 = jnp.full((B, H, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, qc), jnp.float32)
    (o, m, l), _ = lax.scan(body, (o0, m0, l0), jnp.arange(n_kv))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o  # (B, H, qc, hd)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KVH, hd); returns (B, Sq, H, hd).

    Triangular schedule: Q chunks iterate in a static python loop, and each
    only scans the KV chunks its causal mask can reach.
    """
    B, Sq, H, hd = q.shape
    _, Skv, KVH, _ = k.shape
    assert H % KVH == 0, "GQA requires n_heads % n_kv_heads == 0"
    kv_map = jnp.asarray(np.repeat(np.arange(KVH), H // KVH))
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0

    outs = []
    n_q = Sq // q_chunk
    for qi in range(n_q):
        qpos0 = qi * q_chunk
        qs = lax.dynamic_slice_in_dim(q, qpos0, q_chunk, axis=1)
        if causal:
            n_kv = min((qpos0 + q_chunk + kv_chunk - 1) // kv_chunk, Skv // kv_chunk)
        else:
            n_kv = Skv // kv_chunk
        o = _chunk_attn_scan(qs, k, v, kv_map, qpos0, causal, kv_chunk, n_kv)
        outs.append(o)
    o = jnp.concatenate(outs, axis=2) if n_q > 1 else outs[0]  # (B, H, Sq, hd)
    o = jnp.moveaxis(o, 1, 2)  # (B, Sq, H, hd)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (one new token against a KV cache)
# ---------------------------------------------------------------------------

def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, S, KVH, hd)
    v_cache: jnp.ndarray,
    cur_len: jnp.ndarray,  # scalar or (B,) — number of valid cache positions
) -> jnp.ndarray:
    B, _, H, hd = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    qg = q.reshape(B, 1, KVH, G, hd)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(hd).astype(jnp.float32)  # (B, KVH, G, 1, S)
    pos = jnp.arange(S)
    valid = pos[None] < jnp.broadcast_to(jnp.asarray(cur_len)[..., None], (B, S))
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = jnp.moveaxis(o, 3, 1).reshape(B, 1, H, hd)
    return o.astype(q.dtype)


def decode_attention_q8(
    q: jnp.ndarray,  # (B, 1, H, hd) activation dtype
    k_q: jnp.ndarray,  # (B, S, KVH, hd) int8
    k_scale: jnp.ndarray,  # (B, S, KVH) fp32
    v_q: jnp.ndarray,  # (B, S, KVH, hd) int8
    v_scale: jnp.ndarray,  # (B, S, KVH) fp32
    cur_len: jnp.ndarray,
) -> jnp.ndarray:
    """Decode attention reading an int8 KV cache.

    Scores run as an int8 x int8 -> int32 dot (the TPU int8 MXU path) with
    the per-(position, kv-head) K scales and per-query Q scales factored out
    of the contraction; PV dequantizes V per chunkless read (probs stay fp).
    The memory-term win is on the K/V reads: 1 byte/elem instead of 2.
    """
    B, _, H, hd = q.shape
    _, S, KVH, _ = k_q.shape
    G = H // KVH
    # per-(B, head) symmetric quantization of the single query
    q32 = q.astype(jnp.float32)
    q_amax = jnp.max(jnp.abs(q32), axis=-1, keepdims=True)  # (B,1,H,1)
    q_scale = jnp.maximum(q_amax / 127.0, 1e-8)
    qq = jnp.clip(jnp.round(q32 / q_scale), -127, 127).astype(jnp.int8)
    qg = qq.reshape(B, 1, KVH, G, hd)
    s_int = jnp.einsum(
        "bqhgd,bshd->bhgqs", qg, k_q, preferred_element_type=jnp.int32
    )  # int8 x int8 -> int32
    qs = q_scale.reshape(B, KVH, G)[:, :, :, None, None]  # (B,KVH,G,1,1)
    ks = k_scale.transpose(0, 2, 1)[:, :, None, None, :]  # (B,KVH,1,1,S)
    s = s_int.astype(jnp.float32) * qs * ks / jnp.sqrt(hd).astype(jnp.float32)
    pos = jnp.arange(S)
    valid = pos[None] < jnp.broadcast_to(jnp.asarray(cur_len)[..., None], (B, S))
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # PV: fold the per-position V scales into the probabilities so the
    # contraction consumes raw int8 V rows (fp32 accumulation)
    p_scaled = (p * v_scale.transpose(0, 2, 1)[:, :, None, None, :]).astype(
        jnp.bfloat16
    )
    pv = jnp.einsum(
        "bhgqs,bshd->bhgqd", p_scaled, v_q.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    o = jnp.moveaxis(pv, 3, 1).reshape(B, 1, H, hd)
    return o.astype(q.dtype)
