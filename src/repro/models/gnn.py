"""GatedGCN (Bresson & Laurent; benchmarking-gnns arXiv:2003.00982 config).

Message passing is implemented with the JAX-native sparse idiom:
edge-index gathers (jnp.take) + jax.ops.segment_sum scatters — JAX has no
CSR/CSC SpMM, so the gather/segment-reduce pipeline IS the kernel (see
kernel_taxonomy §GNN).  Works on one flat edge list for all four assigned
shapes: full-graph, sampled minibatch subgraphs, giant full-batch, and
block-diagonal batched molecules.

Layer (edge-gated aggregation):
    e'_ij = e_ij + ReLU(LN(A e_ij + B h_i + C h_j))
    eta_ij = sigma(e'_ij) / (sum_{j'->i} sigma(e'_ij') + eps)
    h'_i  = h_i + ReLU(LN(U h_i + sum_{j->i} eta_ij * (V h_j)))

(BatchNorm of the reference impl is replaced by LayerNorm — no running
batch statistics in a pure-functional pipeline; noted in DESIGN.md.)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import (
    KeyGen,
    dtype_of,
    layernorm,
    mlp_apply,
    mlp_init,
    scaled_init,
    softmax_cross_entropy,
)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 16
    d_hidden: int = 70
    d_feat: int = 1433
    d_edge_feat: int = 0  # 0 => learned constant edge init
    n_classes: int = 7
    readout: str = "node"  # "node" | "graph"
    graph_target_dim: int = 1  # for graph-level regression
    dtype: str = "float32"
    param_dtype: str = "float32"
    norm_eps: float = 1e-5
    remat: bool = True

    def param_count(self) -> int:
        d = self.d_hidden
        per_layer = 5 * d * d + 5 * d + 4 * d  # A,B,C,U,V + biases + 2 LN
        head = d * self.n_classes if self.readout == "node" else (
            d * d + d * self.graph_target_dim
        )
        return self.d_feat * d + max(self.d_edge_feat, 1) * d + self.n_layers * per_layer + head


def _layer_init(key, cfg: GNNConfig):
    kg = KeyGen(key)
    d = cfg.d_hidden
    pdt = dtype_of(cfg.param_dtype)
    mats = {
        name: scaled_init(d)(kg(), (d, d), pdt) for name in ["A", "B", "C", "U", "V"]
    }
    mats.update(
        {
            "bA": jnp.zeros((d,), pdt),
            "bU": jnp.zeros((d,), pdt),
            "ln_h": jnp.ones((d,), pdt),
            "ln_h_b": jnp.zeros((d,), pdt),
            "ln_e": jnp.ones((d,), pdt),
            "ln_e_b": jnp.zeros((d,), pdt),
        }
    )
    return mats


def init_params(key, cfg: GNNConfig):
    kg = KeyGen(key)
    pdt = dtype_of(cfg.param_dtype)
    d = cfg.d_hidden
    layer_keys = jax.random.split(kg(), cfg.n_layers)
    p = {
        "node_in": scaled_init(cfg.d_feat)(kg(), (cfg.d_feat, d), pdt),
        "edge_in": scaled_init(max(cfg.d_edge_feat, 1))(
            kg(), (max(cfg.d_edge_feat, 1), d), pdt
        ),
        "layers": jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys),
    }
    if cfg.readout == "node":
        p["head"] = scaled_init(d)(kg(), (d, cfg.n_classes), pdt)
    else:
        p["head_mlp"] = mlp_init(kg, [d, d, cfg.graph_target_dim], pdt)
    return p


def param_shapes(cfg: GNNConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


def _gated_layer(h, e, lp, src, dst, n_nodes, eps):
    """One GatedGCN layer on a flat edge list."""
    h_src = h[src]  # (E, d) gather
    h_dst = h[dst]
    e_new = e + jax.nn.relu(
        layernorm(e @ lp["A"] + lp["bA"] + h_dst @ lp["B"] + h_src @ lp["C"],
                  lp["ln_e"], lp["ln_e_b"], eps)
    )
    gate = jax.nn.sigmoid(e_new)  # (E, d)
    msg = gate * (h_src @ lp["V"])  # (E, d)
    agg = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
    denom = jax.ops.segment_sum(gate, dst, num_segments=n_nodes)
    agg = agg / (denom + 1e-6)
    h_new = h + jax.nn.relu(
        layernorm(h @ lp["U"] + lp["bU"] + agg, lp["ln_h"], lp["ln_h_b"], eps)
    )
    # keep the activation dtype stable under mixed precision (params may be
    # fp32 while states run bf16)
    return h_new.astype(h.dtype), e_new.astype(e.dtype)


def backbone(params, cfg: GNNConfig, graph, constrain=lambda x, n: x):
    """graph = {"nodes": (N, d_feat), "edges": (2, E) int32,
                "edge_feats": optional (E, d_edge)} -> node states (N, d)."""
    dt = dtype_of(cfg.dtype)
    nodes = graph["nodes"].astype(dt)
    src, dst = graph["edges"][0], graph["edges"][1]
    n_nodes = nodes.shape[0]
    h = nodes @ params["node_in"]
    if cfg.d_edge_feat > 0:
        e = graph["edge_feats"].astype(dt) @ params["edge_in"]
    else:
        e = jnp.broadcast_to(params["edge_in"][0], (src.shape[0], cfg.d_hidden)).astype(dt)
    h = constrain(h, "nodes")
    e = constrain(e, "edges")

    def body(carry, lp):
        h, e = carry
        h, e = _gated_layer(h, e, lp, src, dst, n_nodes, cfg.norm_eps)
        return (constrain(h, "nodes"), constrain(e, "edges")), None

    fn = jax.checkpoint(body) if cfg.remat else body
    (h, e), _ = lax.scan(fn, (h, e), params["layers"])
    return h


def node_logits(params, cfg: GNNConfig, graph, constrain=lambda x, n: x):
    h = backbone(params, cfg, graph, constrain)
    return h @ params["head"]


def graph_prediction(params, cfg: GNNConfig, graph, n_graphs: int, constrain=lambda x, n: x):
    """graph additionally holds graph_ids (N,); n_graphs is STATIC (closure
    it via functools.partial before jit — segment_sum needs a static size)."""
    h = backbone(params, cfg, graph, constrain)
    gid = graph["graph_ids"]
    pooled = jax.ops.segment_sum(h, gid, num_segments=n_graphs)
    counts = jax.ops.segment_sum(jnp.ones((h.shape[0], 1), h.dtype), gid, n_graphs)
    pooled = pooled / jnp.maximum(counts, 1.0)
    return mlp_apply(params["head_mlp"], pooled)


def train_loss(params, cfg: GNNConfig, batch, n_graphs: int = 0, constrain=lambda x, n: x):
    """Node classification (masked CE) or graph regression (MSE).

    For graph readout, pass n_graphs statically (functools.partial) pre-jit.
    """
    if cfg.readout == "node":
        logits = node_logits(params, cfg, batch, constrain)
        mask = batch.get("label_mask")
        ce = softmax_cross_entropy(logits, batch["labels"])
        if mask is not None:
            return (ce * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return ce.mean()
    pred = graph_prediction(params, cfg, batch, n_graphs, constrain)
    tgt = batch["graph_targets"].astype(jnp.float32)
    return jnp.mean(jnp.square(pred.astype(jnp.float32).squeeze(-1) - tgt))
