"""Shared model building blocks (pure-JAX pytree style, no flax)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import numpy as np

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[
        name
    ]


# ---------------------------------------------------------------------------
# Initializers (all take (key, shape, dtype))
# ---------------------------------------------------------------------------

def normal_init(key, shape, dtype, stddev=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)


def scaled_init(fan_in: int):
    def init(key, shape, dtype):
        std = 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return init


def zeros_init(key, shape, dtype):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    del key
    return jnp.ones(shape, dtype)


class KeyGen:
    """Deterministic key splitter for readable init code."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layernorm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def batchnorm_infer(x, scale, bias, mean, var, eps=1e-5):
    """Inference-mode batch norm (folded stats)."""
    inv = jax.lax.rsqrt(var.astype(jnp.float32) + eps)
    y = (x.astype(jnp.float32) - mean) * inv * scale + bias
    return y.astype(x.dtype)


ACTIVATIONS: Dict[str, Callable] = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "dice_proxy": jax.nn.sigmoid,  # DIN's Dice ~ data-adaptive PReLU; see recsys.py
    "identity": lambda x: x,
}


# ---------------------------------------------------------------------------
# Simple MLP (used by recsys towers and GNN heads)
# ---------------------------------------------------------------------------

def mlp_init(kg: KeyGen, dims, dtype, bias=True):
    """dims = [in, h1, h2, ..., out]"""
    layers = []
    for i in range(len(dims) - 1):
        layer = {"w": scaled_init(dims[i])(kg(), (dims[i], dims[i + 1]), dtype)}
        if bias:
            layer["b"] = jnp.zeros((dims[i + 1],), dtype)
        layers.append(layer)
    return layers


def mlp_apply(layers, x, act="relu", final_act="identity"):
    a = ACTIVATIONS[act]
    fa = ACTIVATIONS[final_act]
    for i, layer in enumerate(layers):
        x = x @ layer["w"]
        if "b" in layer:
            x = x + layer["b"]
        x = a(x) if i < len(layers) - 1 else fa(x)
    return x


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-example CE; logits (..., V) float, labels (...) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - picked


def binary_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
