"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M; hf] — llama-arch small, tied.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""

from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "smollm-135m"
FAMILY = "lm"
SHAPES = LM_SHAPES


def make_config(shape_id=None) -> LMConfig:
    del shape_id
    return LMConfig(
        name=ARCH_ID,
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_ff=1536,
        vocab=49152,
        tie_embeddings=True,
    )
