"""Architecture registry: the 10 assigned archs, reduced smoke variants,
and helpers shared by the launcher/tests/benchmarks."""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs import (
    bst,
    dcn_v2,
    dien,
    din,
    gatedgcn,
    grok_1_314b,
    minitron_4b,
    phi3_5_moe_42b_a6_6b,
    smollm_135m,
    yi_9b,
)
from repro.configs.shapes import ShapeCell

_MODULES = [
    phi3_5_moe_42b_a6_6b,
    grok_1_314b,
    yi_9b,
    minitron_4b,
    smollm_135m,
    gatedgcn,
    dien,
    bst,
    dcn_v2,
    din,
]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys
    module: object

    def make_config(self, shape_id=None):
        return self.module.make_config(shape_id)

    @property
    def shapes(self) -> Dict[str, ShapeCell]:
        return self.module.SHAPES


ARCHS: Dict[str, ArchSpec] = {
    m.ARCH_ID: ArchSpec(m.ARCH_ID, m.FAMILY, m) for m in _MODULES
}

ARCH_IDS = list(ARCHS)


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return ARCHS[arch_id]


def all_cells():
    """Every assigned (arch, shape) pair — the 40 dry-run cells."""
    return [
        (arch_id, shape_id)
        for arch_id, spec in ARCHS.items()
        for shape_id in spec.shapes
    ]


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests (same family/topology, tiny dims)
# ---------------------------------------------------------------------------

def reduced_config(arch_id: str):
    spec = get_arch(arch_id)
    if spec.family == "lm":
        full = spec.make_config()
        return dataclasses.replace(
            full,
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            d_ff=128,
            vocab=512,
            moe_experts=4 if full.is_moe else 0,
            dtype="float32",
            param_dtype="float32",
            q_chunk=32,
            kv_chunk=32,
            ce_chunk=32,
            moe_group=64,
        )
    if spec.family == "gnn":
        full = spec.make_config("full_graph_sm")
        return dataclasses.replace(
            full, n_layers=2, d_hidden=16, d_feat=12, n_classes=5
        )
    # recsys
    full = spec.make_config()
    kw = dict(
        embed_dim=8,
        item_vocab=1000,
        cate_vocab=100,
        mlp=(32, 16),
    )
    if full.kind == "dien":
        kw["gru_dim"] = 16
    if full.kind == "bst":
        kw["n_heads"] = 4
    if full.kind == "dcn":
        kw["sparse_vocabs"] = tuple([100] * full.n_sparse)
        kw["n_cross_layers"] = 2
    if full.kind == "din":
        kw["attn_mlp"] = (16, 8)
    if full.seq_len:
        kw["seq_len"] = 10
    return dataclasses.replace(full, **kw)
