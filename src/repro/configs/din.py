"""din [arXiv:1706.06978; paper] — Deep Interest Network, target attention.

embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80.
"""

from repro.configs.shapes import RECSYS_SHAPES
from repro.models.recsys import RecSysConfig

ARCH_ID = "din"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def make_config(shape_id=None) -> RecSysConfig:
    del shape_id
    return RecSysConfig(
        name=ARCH_ID,
        kind="din",
        embed_dim=18,
        seq_len=100,
        attn_mlp=(80, 40),
        mlp=(200, 80),
        item_vocab=1_000_000,
        cate_vocab=10_000,
    )
