"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct; hf]

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2.
"""

from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"
FAMILY = "lm"
SHAPES = LM_SHAPES


def make_config(shape_id=None) -> LMConfig:
    del shape_id
    return LMConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab=32064,
        moe_experts=16,
        moe_top_k=2,
    )
