"""minitron-4b [arXiv:2407.14679; hf] — pruned nemotron, dense GQA.

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""

from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "minitron-4b"
FAMILY = "lm"
SHAPES = LM_SHAPES


def make_config(shape_id=None) -> LMConfig:
    del shape_id
    return LMConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=9216,
        vocab=256000,
    )
