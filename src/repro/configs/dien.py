"""dien [arXiv:1809.03672; unverified] — interest evolution (GRU + AUGRU).

embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80 interaction=augru.
"""

from repro.configs.shapes import RECSYS_SHAPES
from repro.models.recsys import RecSysConfig

ARCH_ID = "dien"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def make_config(shape_id=None) -> RecSysConfig:
    del shape_id
    return RecSysConfig(
        name=ARCH_ID,
        kind="dien",
        embed_dim=18,
        seq_len=100,
        gru_dim=108,
        mlp=(200, 80),
        item_vocab=1_000_000,
        cate_vocab=10_000,
    )
