"""Assigned input-shape cells, one table per architecture family.

Every (arch x shape) pair is a dry-run cell; `kind` selects which step
function is lowered (train_step vs serve_step variants), per the assignment:
decode_*/long_* lower serve_step (one token + KV cache), not train_step.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    shape_id: str
    kind: str  # lm_train | lm_prefill | lm_decode |
    #            gnn_full | gnn_sampled | gnn_batched |
    #            rs_train | rs_serve | rs_retrieval
    meta: dict


LM_SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "lm_train", {"seq": 4096, "batch": 256}),
    "prefill_32k": ShapeCell("prefill_32k", "lm_prefill", {"seq": 32768, "batch": 32}),
    "decode_32k": ShapeCell("decode_32k", "lm_decode", {"seq": 32768, "batch": 128}),
    # long-context decode: one token against a 512k-entry KV cache.  All five
    # assigned LM archs are full-attention; decode is LINEAR in seq (the
    # quadratic concern applies to prefill only — noted in DESIGN.md).
    "long_500k": ShapeCell("long_500k", "lm_decode", {"seq": 524288, "batch": 1}),
}

GNN_SHAPES: Dict[str, ShapeCell] = {
    # cora full-batch
    "full_graph_sm": ShapeCell(
        "full_graph_sm",
        "gnn_full",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7},
    ),
    # reddit, sampled 2-hop subgraph: 1024 seeds, fanout 15 then 10
    "minibatch_lg": ShapeCell(
        "minibatch_lg",
        "gnn_sampled",
        {
            "base_nodes": 232_965,
            "base_edges": 114_615_892,
            "batch_nodes": 1024,
            "fanout": (15, 10),
            "sub_nodes": 1024 * (1 + 15 + 150),  # 169,984
            "sub_edges": 1024 * 15 + 1024 * 15 * 10,  # 168,960
            "d_feat": 602,
            "n_classes": 41,
        },
    ),
    # ogbn-products full-batch
    "ogb_products": ShapeCell(
        "ogb_products",
        "gnn_full",
        {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100, "n_classes": 47},
    ),
    # ZINC-like batched molecules, graph-level regression
    "molecule": ShapeCell(
        "molecule",
        "gnn_batched",
        {
            "n_graphs": 128,
            "nodes_per_graph": 30,
            "edges_per_graph": 64,
            "d_feat": 28,
            "d_edge_feat": 4,
        },
    ),
}

RECSYS_SHAPES: Dict[str, ShapeCell] = {
    "train_batch": ShapeCell("train_batch", "rs_train", {"batch": 65536}),
    "serve_p99": ShapeCell("serve_p99", "rs_serve", {"batch": 512}),
    "serve_bulk": ShapeCell("serve_bulk", "rs_serve", {"batch": 262144}),
    "retrieval_cand": ShapeCell(
        "retrieval_cand", "rs_retrieval", {"batch": 1, "n_candidates": 1_000_000}
    ),
}


def pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult
