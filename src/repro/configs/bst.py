"""bst [arXiv:1905.06874; paper] — Behavior Sequence Transformer (Alibaba).

embed_dim=32 seq_len=20 n_blocks=1 n_heads=8 mlp=1024-512-256.
"""

from repro.configs.shapes import RECSYS_SHAPES
from repro.models.recsys import RecSysConfig

ARCH_ID = "bst"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def make_config(shape_id=None) -> RecSysConfig:
    del shape_id
    return RecSysConfig(
        name=ARCH_ID,
        kind="bst",
        embed_dim=32,
        seq_len=20,
        n_blocks=1,
        n_heads=8,
        mlp=(1024, 512, 256),
        item_vocab=1_000_000,
        cate_vocab=10_000,
    )
