"""dcn-v2 [arXiv:2008.13535; paper] — deep & cross v2, full-rank cross.

n_dense=13 n_sparse=26 embed_dim=16 n_cross_layers=3 mlp=1024-1024-512.
"""

from repro.configs.shapes import RECSYS_SHAPES
from repro.models.recsys import RecSysConfig, dcn_default_vocabs

ARCH_ID = "dcn-v2"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES


def make_config(shape_id=None) -> RecSysConfig:
    del shape_id
    return RecSysConfig(
        name=ARCH_ID,
        kind="dcn",
        embed_dim=16,
        n_dense=13,
        n_sparse=26,
        n_cross_layers=3,
        mlp=(1024, 1024, 512),
        sparse_vocabs=dcn_default_vocabs(26),
    )
