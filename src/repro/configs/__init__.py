from repro.configs.registry import ARCHS, ARCH_IDS, all_cells, get_arch, reduced_config
from repro.configs.shapes import GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES, ShapeCell, pad_to
