"""grok-1-314b [hf:xai-org/grok-1; unverified]

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8 experts top-2.
"""

from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "grok-1-314b"
FAMILY = "lm"
SHAPES = LM_SHAPES


def make_config(shape_id=None) -> LMConfig:
    del shape_id
    return LMConfig(
        name=ARCH_ID,
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=32768,
        vocab=131072,
        moe_experts=8,
        moe_top_k=2,
    )
