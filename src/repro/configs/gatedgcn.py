"""gatedgcn [arXiv:2003.00982; paper] — benchmarking-gnns config.

n_layers=16 d_hidden=70 aggregator=gated.  d_feat / n_classes / readout are
dataset (shape) properties, so the config is shape-dependent.
"""

from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn import GNNConfig

ARCH_ID = "gatedgcn"
FAMILY = "gnn"
SHAPES = GNN_SHAPES


def make_config(shape_id="full_graph_sm") -> GNNConfig:
    meta = GNN_SHAPES[shape_id].meta
    if shape_id == "molecule":
        return GNNConfig(
            name=ARCH_ID,
            n_layers=16,
            d_hidden=70,
            d_feat=meta["d_feat"],
            d_edge_feat=meta["d_edge_feat"],
            readout="graph",
            graph_target_dim=1,
        )
    return GNNConfig(
        name=ARCH_ID,
        n_layers=16,
        d_hidden=70,
        d_feat=meta["d_feat"],
        n_classes=meta["n_classes"],
        readout="node",
    )
