"""yi-9b [arXiv:2403.04652; hf] — llama-arch GQA dense.

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""

from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "yi-9b"
FAMILY = "lm"
SHAPES = LM_SHAPES


def make_config(shape_id=None) -> LMConfig:
    del shape_id
    return LMConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        d_ff=11008,
        vocab=64000,
    )
