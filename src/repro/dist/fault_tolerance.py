"""Fault tolerance for long training runs and sharded scans: injected faults
(restart tests), shard-retry bookkeeping for the sharded streaming scanner,
retry classification (retryable I/O vs. fatal programming errors), jittered
exponential backoff, a per-step straggler watchdog, and the abort signal it
raises.  DESIGN.md §12 is the contract.

The watchdog keeps a rolling window of recent step durations and flags a step
as a straggler when it exceeds ``factor`` x the rolling median.  What happens
then is the ``policy``:

  * ``"log"``        — record the event, keep going (production default:
                       stragglers are noted for the capacity dashboard).
  * ``"checkpoint"`` — record the event and tell the training loop to cut a
                       checkpoint now (pre-emption is probably imminent).
  * ``"raise"``      — raise :class:`StragglerAbort` so a supervisor can
                       reschedule the job (used by the elastic tests).
"""

from __future__ import annotations

import dataclasses
import random
import statistics
import time
from typing import Callable, List, Optional


class InjectedFault(RuntimeError):
    """Simulated node failure, raised mid-run by tests/launchers."""


class FatalScanError(RuntimeError):
    """A source/scanner error that retrying can never fix (auth failure,
    object permanently gone, corrupt metadata).  Classified non-retryable by
    :func:`default_is_retryable`, so it surfaces on the first attempt."""


class StragglerAbort(RuntimeError):
    """Raised by StepWatchdog(policy="raise") when a step stalls."""


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float
    factor: float


@dataclasses.dataclass
class ShardRetry:
    """One failed attempt at scanning a stream shard (DESIGN.md §10): the
    shard was re-opened from its byte range and rescanned from scratch —
    a partial scan's already-dispatched chunks are simply discarded, so a
    retried shard's contribution is identical to a clean first pass."""

    shard: int
    attempt: int
    error: str


# Programming errors: retrying re-runs the identical code on the identical
# inputs, so these can only fail the same way again — burning retries on them
# hides the traceback behind seconds of pointless backoff.
_NON_RETRYABLE = (
    TypeError,
    ValueError,
    KeyError,
    IndexError,
    AttributeError,
    NotImplementedError,
    AssertionError,
)


def default_is_retryable(exc: BaseException) -> bool:
    """The retry classifier: transient I/O may heal, programming errors and
    :class:`FatalScanError` never do.  ``ValueError`` covers plan/spec
    construction AND data corruption (e.g. a truncated gzip stream) — both
    deterministic, neither helped by a rescan of the same bytes."""
    return not isinstance(exc, _NON_RETRYABLE + (FatalScanError,))


@dataclasses.dataclass
class BackoffPolicy:
    """Jittered exponential backoff: attempt i waits
    ``min(base_s * factor**i, max_s)`` scaled by a uniform jitter in
    ``[1 - jitter, 1 + jitter]`` (decorrelates a fleet of shards hammering
    the same recovering object store).  ``seed`` makes the jitter sequence
    deterministic for tests."""

    base_s: float = 0.05
    factor: float = 2.0
    max_s: float = 2.0
    jitter: float = 0.5
    seed: Optional[int] = None

    def __post_init__(self):
        if not (0.0 <= self.jitter <= 1.0):
            raise ValueError("jitter must be in [0, 1]")
        if self.base_s < 0 or self.factor < 1 or self.max_s < 0:
            raise ValueError("backoff needs base_s/max_s >= 0, factor >= 1")
        self._rng = random.Random(self.seed)

    def delay_s(self, attempt: int) -> float:
        d = min(self.base_s * self.factor ** attempt, self.max_s)
        if self.jitter:
            d *= 1.0 + self.jitter * (2.0 * self._rng.random() - 1.0)
        return max(d, 0.0)


def run_with_retries(
    fn,
    *,
    retries: int,
    on_failure=None,
    is_retryable: Optional[Callable[[BaseException], bool]] = None,
    backoff: Optional[BackoffPolicy] = None,
    sleep=time.sleep,
    recorder=None,
    label: str = "task",
):
    """Call ``fn()``; on a RETRYABLE exception retry up to ``retries`` more
    times (waiting ``backoff.delay_s(attempt)`` between attempts when a
    policy is given), then re-raise.  Non-retryable errors — programming
    errors per :func:`default_is_retryable`, or whatever the ``is_retryable``
    hook rejects — re-raise immediately: a TypeError from plan construction
    must not burn the retry budget a flaky object store needs.
    ``on_failure(attempt, exc)`` observes every failed attempt, fatal ones
    included (the sharded scanner logs a :class:`ShardRetry` there).

    ``recorder`` (a :class:`repro.obs.recorder.Recorder`) gets one
    structured ``retry`` event per retried attempt and one ``retry_exhausted``
    / ``retry_fatal`` event when the loop gives up, each tagged with
    ``label`` — the flight-recorder view of the retry budget (DESIGN.md
    §13)."""
    classify = default_is_retryable if is_retryable is None else is_retryable
    if recorder is None:
        from repro.obs.recorder import NULL as recorder
    for attempt in range(retries + 1):
        try:
            return fn()
        except Exception as exc:  # noqa: BLE001 - a shard may die any way it likes
            if on_failure is not None:
                on_failure(attempt, exc)
            retryable = classify(exc)
            if attempt == retries or not retryable:
                recorder.event(
                    "retry_exhausted" if retryable else "retry_fatal",
                    task=label, attempt=attempt, error=repr(exc),
                )
                raise
            recorder.event(
                "retry", task=label, attempt=attempt, error=repr(exc)
            )
            if backoff is not None:
                sleep(backoff.delay_s(attempt))


class StepWatchdog:
    """Detect steps that run anomalously long vs the rolling median.

    Usage::

        wd = StepWatchdog(factor=3.0, policy="log")
        wd.start_step(step)
        ...run the step...
        action = wd.end_step()   # policy string if straggling, else None
    """

    def __init__(
        self,
        factor: float = 3.0,
        policy: str = "log",
        window: int = 64,
        min_history: int = 3,
        min_duration_s: float = 1e-4,
    ):
        if policy not in ("log", "checkpoint", "raise"):
            raise ValueError(f"unknown watchdog policy {policy!r}")
        self.factor = factor
        self.policy = policy
        self.window = window
        self.min_history = min_history
        self.min_duration_s = min_duration_s
        self.events: List[StragglerEvent] = []
        self._durations: List[float] = []
        self._step: Optional[int] = None
        self._t0: Optional[float] = None

    def start_step(self, step: int) -> None:
        self._step = step
        self._t0 = time.perf_counter()

    def end_step(self) -> Optional[str]:
        if self._t0 is None:
            raise RuntimeError("end_step() without start_step()")
        dur = time.perf_counter() - self._t0
        step = self._step
        self._t0 = None
        straggler = False
        if len(self._durations) >= self.min_history:
            med = statistics.median(self._durations)
            if dur > max(self.factor * med, self.min_duration_s):
                straggler = True
                self.events.append(
                    StragglerEvent(
                        step=int(step), duration_s=dur, median_s=med,
                        factor=dur / max(med, 1e-12),
                    )
                )
        if not straggler:
            # stragglers don't pollute the baseline window
            self._durations.append(dur)
            if len(self._durations) > self.window:
                self._durations = self._durations[-self.window :]
            return None
        if self.policy == "raise":
            raise StragglerAbort(
                f"step {step} took {dur * 1e3:.1f}ms "
                f"(median {statistics.median(self._durations) * 1e3:.1f}ms)"
            )
        return self.policy
