"""Fault tolerance for long training runs and sharded scans: injected faults
(restart tests), shard-retry bookkeeping for the sharded streaming scanner,
a per-step straggler watchdog, and the abort signal it raises.

The watchdog keeps a rolling window of recent step durations and flags a step
as a straggler when it exceeds ``factor`` x the rolling median.  What happens
then is the ``policy``:

  * ``"log"``        — record the event, keep going (production default:
                       stragglers are noted for the capacity dashboard).
  * ``"checkpoint"`` — record the event and tell the training loop to cut a
                       checkpoint now (pre-emption is probably imminent).
  * ``"raise"``      — raise :class:`StragglerAbort` so a supervisor can
                       reschedule the job (used by the elastic tests).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import List, Optional


class InjectedFault(RuntimeError):
    """Simulated node failure, raised mid-run by tests/launchers."""


class StragglerAbort(RuntimeError):
    """Raised by StepWatchdog(policy="raise") when a step stalls."""


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float
    factor: float


@dataclasses.dataclass
class ShardRetry:
    """One failed attempt at scanning a stream shard (DESIGN.md §10): the
    shard was re-opened from its byte range and rescanned from scratch —
    a partial scan's already-dispatched chunks are simply discarded, so a
    retried shard's contribution is identical to a clean first pass."""

    shard: int
    attempt: int
    error: str


def run_with_retries(fn, *, retries: int, on_failure=None):
    """Call ``fn()``; on exception retry up to ``retries`` more times, then
    re-raise.  ``on_failure(attempt, exc)`` observes every failed attempt
    (the sharded scanner logs a :class:`ShardRetry` there)."""
    for attempt in range(retries + 1):
        try:
            return fn()
        except Exception as exc:  # noqa: BLE001 - a shard may die any way it likes
            if on_failure is not None:
                on_failure(attempt, exc)
            if attempt == retries:
                raise


class StepWatchdog:
    """Detect steps that run anomalously long vs the rolling median.

    Usage::

        wd = StepWatchdog(factor=3.0, policy="log")
        wd.start_step(step)
        ...run the step...
        action = wd.end_step()   # policy string if straggling, else None
    """

    def __init__(
        self,
        factor: float = 3.0,
        policy: str = "log",
        window: int = 64,
        min_history: int = 3,
        min_duration_s: float = 1e-4,
    ):
        if policy not in ("log", "checkpoint", "raise"):
            raise ValueError(f"unknown watchdog policy {policy!r}")
        self.factor = factor
        self.policy = policy
        self.window = window
        self.min_history = min_history
        self.min_duration_s = min_duration_s
        self.events: List[StragglerEvent] = []
        self._durations: List[float] = []
        self._step: Optional[int] = None
        self._t0: Optional[float] = None

    def start_step(self, step: int) -> None:
        self._step = step
        self._t0 = time.perf_counter()

    def end_step(self) -> Optional[str]:
        if self._t0 is None:
            raise RuntimeError("end_step() without start_step()")
        dur = time.perf_counter() - self._t0
        step = self._step
        self._t0 = None
        straggler = False
        if len(self._durations) >= self.min_history:
            med = statistics.median(self._durations)
            if dur > max(self.factor * med, self.min_duration_s):
                straggler = True
                self.events.append(
                    StragglerEvent(
                        step=int(step), duration_s=dur, median_s=med,
                        factor=dur / max(med, 1e-12),
                    )
                )
        if not straggler:
            # stragglers don't pollute the baseline window
            self._durations.append(dur)
            if len(self._durations) > self.window:
                self._durations = self._durations[-self.window :]
            return None
        if self.policy == "raise":
            raise StragglerAbort(
                f"step {step} took {dur * 1e3:.1f}ms "
                f"(median {statistics.median(self._durations) * 1e3:.1f}ms)"
            )
        return self.policy
