"""Deterministic fault-injection harness for the elastic scan fabric
(DESIGN.md §12).

A :class:`FaultPlan` is a SEEDED, order-independent schedule of injected
faults — read errors, short (truncated) reads, latency spikes, and shard
crashes.  Every injection decision is a pure function of ``(seed, fault
type, operation key)`` via sha256, so the same plan produces the same
faults whether shards run sequentially, threaded, or across processes, and
a property test can sweep ``seed x shard count`` and compare every run
against the clean oracle bit-for-bit.

Faults are TRANSIENT by default: a faulty operation fails
``attempts_per_fault`` times, then heals (per-key counters make this
deterministic too), so the retry/steal machinery it exercises can actually
recover.  ``attempts_per_fault=None`` makes faults permanent — the
retry-exhaustion / :class:`~repro.core.shard_stream.PartialScanResult`
path.

The plan threads through three layers:

  * **sources** — :class:`FaultyRangeSource` wraps any range-partitionable
    source behind the callable ``(start, stop)`` protocol and consults the
    plan at every open and every delivered piece; :class:`FaultyChunkSource`
    does the same for one-shot chunk iterators (e.g. the compressed frame
    feed of a :class:`~repro.core.stream.Compressed` source);
  * **scanners** — ``ShardedStreamScanner(fault_plan=...)`` consults the
    plan at the top of every shard attempt (kind ``"shard"``), simulating a
    whole-shard crash inside the retry scope;
  * **retries** — injected errors are ordinary exceptions, so
    ``run_with_retries`` classifies and retries them exactly like real ones
    (:class:`InjectedReadError` is I/O-shaped and retryable; truncations
    surface as ``ShortRangeRead`` from the scanner's length audit).
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.dist.fault_tolerance import InjectedFault


class InjectedReadError(IOError):
    """Injected transient I/O failure (an object-store 5xx / reset socket).
    An IOError, so the default retry classifier treats it as retryable."""


@dataclasses.dataclass
class FaultEvent:
    """One injected fault, for assertions: which knob fired where."""

    action: str  # "read_error" | "truncate" | "latency" | "crash"
    kind: str    # the operation site ("open", "read", "shard", "remote_get")
    key: object  # the operation's identity at that site


class FaultPlan:
    """Seeded deterministic fault schedule.

    ``*_rate`` knobs are per-operation probabilities in [0, 1]; each
    (action, kind, key) triple draws its own uniform from sha256, so rates
    compose independently and no draw depends on execution order.

    Sites consult the plan through two calls:

      * :meth:`check(kind, key)` — may sleep (latency spike), raise
        :class:`InjectedFault` (crash), or raise :class:`InjectedReadError`
        (read error);
      * :meth:`truncate(kind, key, n)` — how many of an n-byte piece to
        actually deliver (``n`` when no truncation fires; a deterministic
        fraction of ``n`` when one does).

    ``sleep`` is injectable so latency-spike tests need not actually wait.
    """

    def __init__(
        self,
        seed: int = 0,
        *,
        read_error_rate: float = 0.0,
        truncate_rate: float = 0.0,
        latency_rate: float = 0.0,
        latency_s: float = 0.02,
        crash_rate: float = 0.0,
        attempts_per_fault: Optional[int] = 1,
        sleep=time.sleep,
        recorder=None,
    ):
        for name, rate in (
            ("read_error_rate", read_error_rate),
            ("truncate_rate", truncate_rate),
            ("latency_rate", latency_rate),
            ("crash_rate", crash_rate),
        ):
            if not (0.0 <= rate <= 1.0):
                raise ValueError(f"{name} must be in [0, 1], got {rate}")
        if attempts_per_fault is not None and attempts_per_fault < 1:
            raise ValueError("attempts_per_fault must be >= 1 or None")
        self.seed = int(seed)
        self.read_error_rate = read_error_rate
        self.truncate_rate = truncate_rate
        self.latency_rate = latency_rate
        self.latency_s = latency_s
        self.crash_rate = crash_rate
        self.attempts_per_fault = attempts_per_fault
        self.sleep = sleep
        # optional flight recorder (repro.obs): every fired fault becomes a
        # structured "fault" event + faults_injected counter, so a chaos
        # trace shows each injection next to the retry it triggered
        self.recorder = recorder
        self.events: List[FaultEvent] = []
        self._counts: Dict[Tuple[str, str, object], int] = {}
        self._lock = threading.Lock()

    # -- the deterministic core --------------------------------------------

    def _u(self, action: str, kind: str, key) -> float:
        h = hashlib.sha256(
            repr((self.seed, action, kind, key)).encode()
        ).digest()
        return int.from_bytes(h[:8], "big") / 2.0 ** 64

    def _fires(self, action: str, kind: str, key, rate: float) -> bool:
        """Does this (action, site) inject a fault on THIS attempt?  The
        draw is order-independent; the per-key attempt counter makes the
        transient-then-heals behavior deterministic as well."""
        if rate <= 0.0 or self._u(action, kind, key) >= rate:
            return False
        with self._lock:
            n = self._counts.get((action, kind, key), 0) + 1
            self._counts[(action, kind, key)] = n
            if self.attempts_per_fault is not None and n > self.attempts_per_fault:
                return False  # healed: the fault burned its attempts
            self.events.append(FaultEvent(action, kind, key))
        if self.recorder is not None:  # outside the lock: sinks may log
            self.recorder.event(
                "fault", action=action, kind=kind, key=repr(key)
            )
            self.recorder.count("faults_injected")
        return True

    # -- the two site calls -------------------------------------------------

    def check(self, kind: str, key) -> None:
        """Consult the plan at an operation site (ordered: a latency spike
        may precede the failure that aborts the operation)."""
        if self._fires("latency", kind, key, self.latency_rate):
            self.sleep(self.latency_s)
        if self._fires("crash", kind, key, self.crash_rate):
            raise InjectedFault(f"injected crash at {kind} {key!r}")
        if self._fires("read_error", kind, key, self.read_error_rate):
            raise InjectedReadError(f"injected read error at {kind} {key!r}")

    def truncate(self, kind: str, key, n: int) -> int:
        if n > 0 and self._fires("truncate", kind, key, self.truncate_rate):
            # deterministic keep-fraction in [0, 1): a short, nonempty read
            return int(n * self._u("truncate_frac", kind, key))
        return n

    def counts_by_action(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for e in self.events:
                out[e.action] = out.get(e.action, 0) + 1
            return out


# faulty pieces are delivered at this granularity so mid-range faults can
# land between pieces of one large buffer slice, not only at range edges
_FAULT_PIECE_BYTES = 1 << 16


class FaultyRangeSource:
    """A range-partitionable source with plan faults injected at every open
    and every delivered piece — the callable ``(start, stop)`` protocol, so
    it drops into ``ShardedStreamScanner``/`open_range` unchanged.

    Opens consult site ``("open", (start, stop))``; pieces consult
    ``("read", (start, i))`` where ``i`` is the piece index within the open
    (piece granularity is fixed, so the key sequence is deterministic for a
    given range).  A truncation fault ends the delivery short — the
    scanner's per-shard length audit turns that into ``ShortRangeRead``."""

    def __init__(
        self,
        source,
        plan: FaultPlan,
        *,
        total_bytes: Optional[int] = None,
        piece_bytes: int = _FAULT_PIECE_BYTES,
    ):
        # imported here, not at module top: repro.core.shard_stream imports
        # repro.dist.* at module scope, so the reverse edge must stay lazy
        from repro.core.shard_stream import source_total_bytes

        self.source = source
        self.plan = plan
        self.piece_bytes = int(piece_bytes)
        self.total_bytes = source_total_bytes(source, total_bytes)
        self.opens = 0

    def __call__(self, start: int, stop: int) -> Iterator[np.ndarray]:
        from repro.core.shard_stream import open_range
        from repro.core.stream import _as_chunks

        self.opens += 1
        self.plan.check("open", (start, stop))

        def gen():
            i = 0
            for piece in _as_chunks(open_range(self.source, start, stop)):
                for off in range(0, len(piece), self.piece_bytes):
                    sub = piece[off : off + self.piece_bytes]
                    self.plan.check("read", (start, i))
                    keep = self.plan.truncate("read", (start, i), len(sub))
                    i += 1
                    if keep < len(sub):
                        yield sub[:keep]
                        return  # a short read ends the stream, like EOF
                    yield sub

        return gen()


class FaultyChunkSource:
    """Plan faults over a one-shot iterator of byte pieces — for sources
    with no random access (compressed frame feeds, sockets).  Wrap the
    COMPRESSED pieces and hand the wrapper to :class:`Compressed`: a
    truncation here cuts a frame mid-member (the decompressor's truncated-
    stream error), a read error surfaces mid-stream."""

    def __init__(self, pieces, plan: FaultPlan, *, key: str = "stream"):
        self.pieces = pieces
        self.plan = plan
        self.key = key

    def __iter__(self):
        from repro.core.stream import _as_chunks

        for i, piece in enumerate(_as_chunks(self.pieces)):
            self.plan.check("read", (self.key, i))
            keep = self.plan.truncate("read", (self.key, i), len(piece))
            if keep < len(piece):
                yield piece[:keep]
                return
            yield piece
