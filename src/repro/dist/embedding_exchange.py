"""DLRM-style all-to-all embedding exchange for row-sharded tables.

The table is row-sharded over one mesh axis; every device holds the ids of
its slice of the batch (replicated over the table axis).  Lookup runs in
three hops:

  1. bucket my ids by owning shard (fixed ``capacity`` slots per shard, so
     shapes are static) and all-to-all the id buckets along the table axis;
  2. every shard answers the requests that landed on it with a local gather;
  3. all-to-all the vectors back and scatter them to the original id order.

All-to-all volume is nnz * dim / k per hop versus nnz * dim all-reduced by
the simpler psum strategy (models/embedding.py) — the classic DLRM win.

Skew safety: with a fixed per-shard capacity a hot shard can overflow (zipf
ids, or adversarially all ids on one shard).  Overflow is detected on device
and the whole lookup falls back to the exact psum path via lax.cond, so the
result is exact for every id distribution; capacity only controls how often
the cheap path runs.
"""

from __future__ import annotations

from typing import Sequence, Tuple, Union

import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.dist.compat import axis_size, shard_map

AxisNames = Union[str, Tuple[str, ...]]


def make_alltoall_lookup(
    mesh,
    table_axis: str = "model",
    batch_axes: Sequence[str] = ("data",),
    capacity_factor: float = 2.0,
):
    """Build `lookup(table, ids) -> vectors` with table row-sharded over
    ``table_axis`` and ids/outputs sharded over ``batch_axes``."""
    batch_axes = tuple(batch_axes)
    batch_spec = batch_axes[0] if len(batch_axes) == 1 else batch_axes

    def local_lookup(table_shard, ids):
        k = axis_size(table_axis)
        me = lax.axis_index(table_axis)
        rows = table_shard.shape[0]  # rows per shard (V // k)
        n = ids.shape[0]
        cap = max(1, int(-(-n * capacity_factor // k)))

        owner = jnp.clip(ids // rows, 0, k - 1)
        onehot = owner[:, None] == jnp.arange(k)[None, :]  # (n, k)
        counts = onehot.sum(axis=0)  # ids per owning shard
        overflow = (counts > cap).any()

        def a2a_path(_):
            # slot of each id inside its owner's bucket
            pos = jnp.cumsum(onehot, axis=0) - 1  # (n, k)
            pib = jnp.take_along_axis(pos, owner[:, None], axis=1)[:, 0]
            slot = owner * cap + pib  # (n,) in [0, k*cap)
            send = jnp.zeros((k * cap,), ids.dtype).at[slot].set(ids)
            # hop 1: ship id buckets to their owners
            recv = lax.all_to_all(
                send.reshape(k, cap), table_axis, split_axis=0, concat_axis=0,
                tiled=False,
            ).reshape(k, cap)
            # hop 2: answer requests with a local gather
            local = jnp.clip(recv - me * rows, 0, rows - 1)
            vals = table_shard[local]  # (k, cap, d)
            # hop 3: ship vectors back and restore the original id order
            back = lax.all_to_all(
                vals, table_axis, split_axis=0, concat_axis=0, tiled=False
            )
            return back.reshape(k * cap, -1)[slot]

        def psum_path(_):
            mine = owner == me
            local = jnp.where(mine, ids - me * rows, 0)
            v = table_shard[local] * mine[:, None].astype(table_shard.dtype)
            return lax.psum(v, table_axis)

        return lax.cond(~overflow, a2a_path, psum_path, operand=None)

    return shard_map(
        local_lookup,
        mesh=mesh,
        in_specs=(P(table_axis, None), P(batch_spec)),
        out_specs=P(batch_spec, None),
        check_vma=False,
    )
