"""Version shims for jax APIs that moved between releases.

`jax.shard_map` (with its `check_vma` flag) only exists on newer jax; on the
0.4.x line the implementation lives in `jax.experimental.shard_map` and the
replication check is spelled `check_rep`.  Everything in this repo goes
through this wrapper so the call sites stay written against the new API.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` with graceful fallback to jax.experimental.shard_map."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=check_vma,
            )
        except TypeError:  # newer-but-not-newest jax: flag still called check_rep
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_vma,
            )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def axis_size(axis_name) -> int:
    """`lax.axis_size` inside shard_map/pmap bodies, on any jax version.

    On jax without `lax.axis_size`, `lax.psum(1, name)` folds to the static
    axis size (a Python int), which is what the ppermute builders need."""
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def cost_analysis_dict(compiled) -> dict:
    """`compiled.cost_analysis()` returns a per-partition list on jax 0.4.x
    and a flat dict on newer jax; normalize to a dict (first partition)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}
