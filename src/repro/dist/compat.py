"""Version shims for jax APIs that moved between releases, and the small
collective helpers the sharded-stream merge rides on.

`jax.shard_map` (with its `check_vma` flag) only exists on newer jax; on the
0.4.x line the implementation lives in `jax.experimental.shard_map` and the
replication check is spelled `check_rep`.  `jax.make_mesh` only exists from
0.4.35.  Everything in this repo goes through these wrappers so the call
sites stay written against the new API — and the CI jax-version matrix
(oldest supported pin / latest) exercises both branches of every shim.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """`jax.shard_map` with graceful fallback to jax.experimental.shard_map."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=check_vma,
            )
        except TypeError:  # newer-but-not-newest jax: flag still called check_rep
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_vma,
            )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def axis_size(axis_name) -> int:
    """`lax.axis_size` inside shard_map/pmap bodies, on any jax version.

    On jax without `lax.axis_size`, `lax.psum(1, name)` folds to the static
    axis size (a Python int), which is what the ppermute builders need."""
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def cost_analysis_dict(compiled) -> dict:
    """`compiled.cost_analysis()` returns a per-partition list on jax 0.4.x
    and a flat dict on newer jax; normalize to a dict (first partition)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def make_mesh(shape: Sequence[int], axis_names, *, devices=None):
    """`jax.make_mesh` (0.4.35+) with a manual-Mesh fallback for older jax,
    plus an explicit `devices` override the shard-stream entrypoint uses to
    build a mesh over a device SUBSET (jax.make_mesh always takes all)."""
    shape = tuple(int(s) for s in shape)
    if devices is None and hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, tuple(axis_names))
    from jax.sharding import Mesh

    devs = list(jax.devices() if devices is None else devices)
    n = int(np.prod(shape))
    if len(devs) < n:
        raise ValueError(f"mesh {shape} needs {n} devices, have {len(devs)}")
    arr = np.empty(n, dtype=object)
    for i, d in enumerate(devs[:n]):
        arr[i] = d
    return Mesh(arr.reshape(shape), tuple(axis_names))


def _device_of(x):
    """The single device a committed jax.Array lives on (API moved: .devices()
    set on newer jax, .device() method on the early 0.4 line)."""
    devs = getattr(x, "devices", None)
    if callable(devs):
        got = devs()
        return next(iter(got)) if not hasattr(got, "device_kind") else got
    return x.device()  # pragma: no cover - ancient jax


@jax.jit
def _sum_shard_axis(a):
    return a.sum(0)


def sum_across_devices(parts: Sequence[jax.Array]) -> np.ndarray:
    """psum-style merge of per-shard accumulators (same shape/dtype each).

    Parts sharing one device fold with on-device adds; parts spread over D
    devices are assembled — WITHOUT gathering to host first — into one
    device-sharded (D, ...) global array and reduced by a single jitted sum,
    which XLA lowers to an actual cross-device reduction.  This is the
    count-merge collective of the two-level seam rule (DESIGN.md §10)."""
    if not parts:
        raise ValueError("sum_across_devices needs at least one part")
    per_dev: dict = {}
    for p in parts:
        d = _device_of(p)
        acc = per_dev.get(d)
        per_dev[d] = p if acc is None else acc + p
    vals: List[jax.Array] = list(per_dev.values())
    if len(vals) == 1:
        return np.asarray(jax.device_get(vals[0]))
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    mesh = make_mesh((len(vals),), ("shard",), devices=list(per_dev))
    shape = (len(vals),) + tuple(vals[0].shape)
    stacked = jax.make_array_from_single_device_arrays(
        shape, NamedSharding(mesh, P("shard")), [v[None] for v in vals]
    )
    return np.asarray(jax.device_get(_sum_shard_axis(stacked)))


def process_allsum(x: np.ndarray) -> np.ndarray:
    """Sum a host array across jax.distributed processes (identity for a
    single process, so the sharded scanner needs no mode switch)."""
    if jax.process_count() == 1:
        return np.asarray(x)
    from jax.experimental import multihost_utils

    return np.asarray(multihost_utils.process_allgather(np.asarray(x))).sum(0)


def process_allgather_ragged(x: np.ndarray) -> List[np.ndarray]:
    """All-gather a ragged 1-D int64 array across processes; returns one
    array per process (just [x] single-process).

    int64 payloads (global stream positions) are split into two int32 planes
    for the wire — multihost_utils runs under the default x64-disabled config,
    which would silently truncate a direct int64 gather."""
    x = np.asarray(x, np.int64)
    if jax.process_count() == 1:
        return [x]
    from jax.experimental import multihost_utils

    lens = np.asarray(
        multihost_utils.process_allgather(np.asarray([len(x)], np.int32))
    ).reshape(-1)
    cap = max(int(lens.max()), 1)
    lo = np.zeros(cap, np.int32)
    hi = np.zeros(cap, np.int32)
    lo[: len(x)] = (x & 0x7FFFFFFF).astype(np.int32)
    hi[: len(x)] = (x >> 31).astype(np.int32)
    lo_all = np.asarray(multihost_utils.process_allgather(lo))
    hi_all = np.asarray(multihost_utils.process_allgather(hi))
    out = []
    for i in range(len(lens)):
        n = int(lens[i])
        out.append(
            (hi_all[i, :n].astype(np.int64) << 31) | lo_all[i, :n].astype(np.int64)
        )
    return out
