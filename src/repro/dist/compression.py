"""Gradient compression for the data-parallel all-reduce: int8 quantized psum
with error feedback.

Each shard quantizes (grad + residual) to int8 with a shared per-tensor scale
(pmax of the local amax, so every shard uses the same grid and the int32
accumulation is exact), all-reduces the int8 values in int32, and dequantizes
once.  The quantization error is kept as the next step's residual (EF14 /
1-bit-Adam style error feedback), so the bias vanishes over steps:

    residual' + dequant(quant(x)) == x          (exactly, per shard)

Wire volume: 1 byte/element instead of 4 (plus one scalar scale per tensor).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def zeros_residuals(tree):
    """Error-feedback state: fp32 zeros shaped like the gradient tree."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), tree
    )


def _quantized_psum_leaf(g, r, axis_name):
    x = g.astype(jnp.float32) + r
    amax = jnp.max(jnp.abs(x))
    amax = lax.pmax(amax, axis_name)  # shared grid across shards
    scale = jnp.maximum(amax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_r = x - deq  # error feedback: r' + deq == x exactly
    summed = lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32) * scale
    return summed.astype(g.dtype), new_r


def quantized_psum(grads, residuals, axis_name: str):
    """int8+EF all-reduce.  Returns (psum'd grads, new residuals).

    Must run inside shard_map (needs the named axis).  With k shards the
    result approximates lax.psum(grads) with per-element error <= k*scale/2,
    and the error feedback residual removes the bias across steps.
    """
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [_quantized_psum_leaf(g, r, axis_name) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_r = treedef.unflatten([o[1] for o in out])
    return new_g, new_r
