"""Distribution substrate: sharding specs, shard_map compat, fault tolerance,
gradient compression, and the DLRM-style embedding exchange.

Modules here are imported by the launchers (launch/cells.py, launch/train.py)
and by the training loop; they contain no model code.
"""
