"""Sharding strategy tables: PartitionSpec trees for params, optimizer state,
activations, and input batches of all three model families.

Everything here is *spec* construction — pure functions from (config, mesh,
strategy) to PartitionSpec pytrees.  The launchers turn the specs into
NamedShardings; models receive activation constraints through the
``constrain(x, name)`` callback built by :func:`make_constrain`.

Conventions:

  * the "model" mesh axis carries tensor parallelism; every other axis is
    data parallelism (:func:`dp_axes` flattens them);
  * a dim is only sharded when the axis size divides it — otherwise the spec
    silently degrades to replicated on that dim, so the same strategy table
    works on the 16x16 production mesh and a 1-device laptop mesh;
  * LM strategies: ``"tp_sp"`` (Megatron-style tensor parallel + sequence
    parallel residuals), ``"zero_dp"`` (params/optimizer sharded over the dp
    axes, ZeRO-ish); GNN strategies: ``"nodes_sharded"`` / ``"nodes_replicated"``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.train.optimizer import AdamWState

AxisNames = Union[str, Tuple[str, ...], None]


# ---------------------------------------------------------------------------
# Mesh helpers
# ---------------------------------------------------------------------------

def all_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def axis_size(mesh, axes: AxisNames) -> int:
    """Product of the named mesh axis sizes (1 for absent/None axes)."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        if a in mesh.axis_names:
            size *= mesh.shape[a]
    return size


def dp_axes(mesh) -> AxisNames:
    """The data-parallel axes: every mesh axis except "model"."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    if not dp:
        return None
    return dp[0] if len(dp) == 1 else dp


def _axis_if(mesh, axes: AxisNames, dim: int) -> AxisNames:
    """`axes` if its total size divides `dim`, else None (replicate)."""
    if axes is None:
        return None
    return axes if dim % axis_size(mesh, axes) == 0 else None


def tree_to_shardings(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Byte-range partitioning (sharded streaming scans, DESIGN.md §10)
# ---------------------------------------------------------------------------

def range_partition(
    total: int, n_shards: int, *, align: int = 1
) -> Tuple[Tuple[int, int], ...]:
    """n_shards contiguous (start, stop) byte ranges covering [0, total).

    Interior boundaries are rounded DOWN to `align` (the stream seam rule
    needs every shard start on a beta block boundary so chunk-local aligned
    block fingerprints coincide with the global ones); the last shard absorbs
    the un-aligned remainder.  Degenerate shards (start == stop) are legal —
    they own no end positions and scan nothing."""
    total, n_shards, align = int(total), int(n_shards), int(align)
    if total < 0 or n_shards < 1 or align < 1:
        raise ValueError("range_partition needs total >= 0, n_shards/align >= 1")
    bounds = [
        min(total, (total * i) // n_shards // align * align)
        for i in range(n_shards + 1)
    ]
    bounds[0], bounds[-1] = 0, total
    return tuple((bounds[i], bounds[i + 1]) for i in range(n_shards))


def merge_ranges(ranges) -> Tuple[Tuple[int, int], ...]:
    """Sorted union of (start, stop) byte ranges: overlapping and adjacent
    ranges coalesce, empty ranges drop.  The canonical form PartialScanResult
    reports covered/missing coverage in (DESIGN.md §12)."""
    out: list = []
    for s, e in sorted((int(s), int(e)) for s, e in ranges):
        if e <= s:
            continue
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return tuple(out)


def complement_ranges(ranges, total: int) -> Tuple[Tuple[int, int], ...]:
    """[0, total) minus the given ranges (merged first)."""
    out, pos = [], 0
    for s, e in merge_ranges(ranges):
        if s > pos:
            out.append((pos, s))
        pos = max(pos, e)
    if pos < int(total):
        out.append((pos, int(total)))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class StreamShardSpec:
    """Range-partition plan for one logical stream scanned by many hosts.

    Shard i scans ``ranges[i]`` with ``overlap`` bytes of carried prefix
    (the bytes immediately before its start) injected into its first window;
    end-position attribution makes it own exactly the occurrences whose last
    byte falls inside its range."""

    total_bytes: int
    ranges: Tuple[Tuple[int, int], ...]
    overlap: int  # carried prefix bytes at each interior boundary
    align: int    # boundary alignment (the EPSMc beta block)

    @property
    def n_shards(self) -> int:
        return len(self.ranges)

    def prefix_range(self, i: int) -> Tuple[int, int]:
        """Byte range of shard i's injected overlap prefix (empty for i=0 or
        a shard starting at 0)."""
        s = self.ranges[i][0]
        return (max(0, s - self.overlap), s)


def make_stream_shard_spec(
    total: int, n_shards: int, *, overlap: int, align: int
) -> StreamShardSpec:
    if overlap < 0 or overlap % align:
        raise ValueError("overlap must be a non-negative multiple of align")
    return StreamShardSpec(
        total_bytes=int(total),
        ranges=range_partition(total, n_shards, align=align),
        overlap=int(overlap),
        align=int(align),
    )


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------

def make_constrain(mesh, table: dict):
    """Build the `constrain(x, name)` callback models thread through.

    Unknown names and rank-mismatched specs pass through untouched, so one
    table can serve several step functions (train/prefill/decode share names).
    """

    def constrain(x, name):
        spec = table.get(name)
        if spec is None or len(spec) != getattr(x, "ndim", -1):
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def _path_has(path, name: str) -> bool:
    return any(getattr(entry, "key", None) == name for entry in path)


def lm_param_specs(cfg, mesh, strategy: str = "tp_sp"):
    """PartitionSpec tree matching transformer.param_shapes(cfg).

    tp_sp: column-shard the QKV/up projections and row-shard the out/down
    projections over "model" (Megatron); embeddings vocab-sharded.
    zero_dp: shard the largest divisible non-stack dim over the dp axes.
    """
    from repro.models import transformer as tf_mod

    sds = tf_mod.param_shapes(cfg)
    dp = dp_axes(mesh)

    col = {"wq", "wk", "wv", "w1", "w3"}       # output-feature sharded
    row = {"wo", "w2"}                          # input-feature sharded

    def tp_spec(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        stacked = _path_has(path, "layers")     # leading n_layers scan dim
        base = 1 if stacked else 0
        spec = [None] * len(shape)
        if name in col and len(shape) - base == 2:
            spec[-1] = _axis_if(mesh, "model", shape[-1])
        elif name in row and len(shape) - base == 2:
            spec[-2] = _axis_if(mesh, "model", shape[-2])
        elif name == "embed":
            spec[0] = _axis_if(mesh, "model", shape[0])
        elif name == "unembed":
            spec[-1] = _axis_if(mesh, "model", shape[-1])
        elif _path_has(path, "moe") and len(shape) - base >= 2:
            # expert-stacked weights: shard the expert dim over "model"
            spec[base] = _axis_if(mesh, "model", shape[base])
        return P(*spec)

    def zero_spec(path, leaf):
        shape = leaf.shape
        stacked = _path_has(path, "layers")
        base = 1 if stacked else 0
        spec = [None] * len(shape)
        # largest divisible dim (excluding the scan-stack dim) goes to dp
        cands = sorted(range(base, len(shape)), key=lambda i: -shape[i])
        for i in cands:
            if _axis_if(mesh, dp, shape[i]) is not None:
                spec[i] = dp
                break
        return P(*spec)

    fn = zero_spec if strategy == "zero_dp" else tp_spec
    return jax.tree_util.tree_map_with_path(fn, sds)


def lm_activation_table(cfg, mesh, kind: str, B: int, strategy: str = "tp_sp"):
    """name -> PartitionSpec for the constrain() names used by models/transformer."""
    del kind
    dp = dp_axes(mesh)
    bdp = _axis_if(mesh, dp, B)
    mdl_heads = _axis_if(mesh, "model", cfg.n_heads)
    mdl_kv = _axis_if(mesh, "model", cfg.n_kv_heads)
    mdl_ff = _axis_if(mesh, "model", cfg.d_ff)
    mdl_vocab = _axis_if(mesh, "model", cfg.vocab)
    if strategy == "zero_dp":
        # params live on dp; activations stay batch-sharded only
        mdl_heads = mdl_kv = mdl_ff = mdl_vocab = None
    return {
        "residual": P(bdp, None, None),                  # (B, S, d)
        "qkv": P(bdp, None, mdl_heads, None),            # (B, S, H, hd)
        "kv_attn": P(bdp, None, mdl_kv, None),           # (B, S, KV, hd)
        "ffn_hidden": P(bdp, None, mdl_ff),              # (B, S, f)
        "moe_in": P(bdp, None, None),                    # (B, S, d)
        "logits": P(bdp, None, mdl_vocab),               # (B, chunk, V)
        "kv_cache": P(bdp, None, mdl_kv, None),          # (B, S, KV, hd)
        "kv_cache_l": P(bdp, None, mdl_kv, None),        # (B, Smax, KV, hd)
        "kv_cache_scale": P(bdp, None, mdl_kv),          # (B, Smax, KV)
    }


def lm_batch_specs(kind: str, mesh, B: int, strategy: str = "tp_sp"):
    del strategy
    dp = dp_axes(mesh)
    bdp = _axis_if(mesh, dp, B)
    if kind == "lm_train":
        return {"tokens": P(bdp, None), "targets": P(bdp, None)}
    if kind == "lm_prefill":
        return {"tokens": P(bdp, None)}
    if kind == "lm_decode":
        # kcache/vcache are (L, B, Smax, KV, hd)
        return {
            "token": P(bdp, None),
            "kcache": P(None, bdp, None, None, None),
        }
    raise ValueError(f"unknown LM kind {kind!r}")


def opt_state_specs(param_specs) -> AdamWState:
    """AdamW state shards exactly like its params (fp32 moments, ZeRO-1)."""
    return AdamWState(step=P(), m=param_specs, v=param_specs)


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def gnn_param_specs(param_sds):
    """GatedGCN params are tiny (d_hidden ~ 70): replicate everything."""
    return jax.tree_util.tree_map(lambda _: P(), param_sds)


def gnn_activation_table(mesh, strategy: str = "nodes_sharded"):
    if strategy == "nodes_replicated":
        return {}
    ax = all_axes(mesh)
    axes = ax[0] if len(ax) == 1 else ax
    return {"nodes": P(axes, None), "edges": P(axes, None)}


def gnn_batch_specs(mesh, batch_sds):
    ax = all_axes(mesh)
    axes = ax[0] if len(ax) == 1 else ax

    def spec(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if name == "edges":  # (2, E) edge index
            return P(None, _axis_if(mesh, axes, shape[1]))
        if name in ("graph_targets",):
            return P()
        first = _axis_if(mesh, axes, shape[0]) if shape else None
        return P(first, *([None] * (len(shape) - 1))) if shape else P()

    return jax.tree_util.tree_map_with_path(spec, batch_sds)


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def recsys_param_specs(cfg, mesh, param_sds):
    """Row-shard the big embedding tables over "model"; replicate the dense
    towers (they are MBs at most)."""
    del cfg

    def spec(path, leaf):
        shape = leaf.shape
        if (
            _path_has(path, "tables")
            or _leaf_name(path) in ("item_table", "cate_table")
        ) and len(shape) == 2 and shape[0] >= 1024:
            return P(_axis_if(mesh, "model", shape[0]), None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec, param_sds)


def recsys_batch_specs(mesh, batch_sds):
    dp = dp_axes(mesh)

    def spec(leaf):
        shape = leaf.shape
        if not shape:
            return P()
        return P(_axis_if(mesh, dp, shape[0]), *([None] * (len(shape) - 1)))

    return jax.tree_util.tree_map(spec, batch_sds)
