"""Schema gate + deterministic renderer for experiments/benchmarks/.

The committed BENCH_*.json files ARE the repo's perf trajectory;
``experiments/benchmarks/paper_tables.md`` is derived from them and from
nothing else, so the markdown can never drift from the data.  CI's
``benchgate`` job re-runs this script and fails the PR if the regenerated
markdown differs from the committed one (or if any JSON violates its
schema).

    python benchmarks/render_tables.py [--check] [--dir experiments/benchmarks]

Stdlib only on purpose: the gate needs no jax install.  The benchmark
harness (benchmarks/run.py) imports the same renderer after refreshing the
JSONs, so the two writers cannot disagree.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

MD_NAME = "paper_tables.md"

# Every BENCH_*.json row must carry these; per-file extras below.
ROW_REQUIRED = {
    "name": str,
    "us_per_call": (int, float),
    "GBps": (int, float),
    "size_bytes": int,
}
FILE_EXTRAS = {
    "BENCH_multipattern.json": {"P": int, "B": int, "m": int,
                                "speedup_vs_vmap": (int, float)},
    "BENCH_approx.json": {"m": int, "k": int, "ratio_vs_exact": (int, float)},
    "BENCH_dictionary.json": {"P": int, "texture": str, "route": str,
                              "ratio_vs_avg": (int, float)},
    "BENCH_stream.json": {},   # two row families; shared keys only
    "BENCH_shard.json": {"shards": int, "speedup_vs_1shard": (int, float),
                         "devices": int},
    "BENCH_megascan.json": {"groups": int, "k": int,
                            "speedup_vs_pergroup": (int, float)},
    "BENCH_faults.json": {"shards": int, "fault_rate": (int, float),
                          "ratio_vs_clean": (int, float)},
    "BENCH_obs.json": {},      # two row families; shared keys only
    "BENCH_service.json": {"clients": int, "qps": (int, float),
                           "p50_ms": (int, float), "p99_ms": (int, float),
                           "speedup_vs_uncoalesced": (int, float)},
}
# BENCH_paper_tables.json is a dict, not a row list: validated separately.
PAPER_JSON = "BENCH_paper_tables.json"


class SchemaError(ValueError):
    pass


def _check_type(fname, where, key, val, types):
    if not isinstance(val, types) or isinstance(val, bool):
        raise SchemaError(
            f"{fname}: {where}: field {key!r} should be "
            f"{types}, got {type(val).__name__} ({val!r})"
        )
    if isinstance(val, float) and not math.isfinite(val):
        raise SchemaError(f"{fname}: {where}: field {key!r} is not finite")


def split_meta(fname: str, doc):
    """BENCH_*.json is either a bare row list or {"meta": {...}, "rows":
    [...]} — the meta object records measurement caveats (host core count,
    baseline identity) that are not per-row numbers."""
    if isinstance(doc, dict) and "rows" in doc:
        meta = doc.get("meta", {})
        if not isinstance(meta, dict):
            raise SchemaError(f"{fname}: 'meta' must be an object")
        return doc["rows"], meta
    return doc, {}


def validate_rows(fname: str, rows) -> None:
    if not isinstance(rows, list) or not rows:
        raise SchemaError(f"{fname}: expected a non-empty list of row objects")
    required = dict(ROW_REQUIRED, **FILE_EXTRAS.get(fname, {}))
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            raise SchemaError(f"{fname}: row {i} is not an object")
        where = f"row {i} ({row.get('name', '?')})"
        for key, types in required.items():
            if key not in row:
                raise SchemaError(f"{fname}: {where}: missing field {key!r}")
            _check_type(fname, where, key, row[key], types)
        if row["us_per_call"] < 0 or row["GBps"] < 0 or row["size_bytes"] <= 0:
            raise SchemaError(f"{fname}: {where}: non-positive measurement")


def validate_paper(fname: str, doc) -> None:
    if not isinstance(doc, dict) or "tables" not in doc or "size_bytes" not in doc:
        raise SchemaError(f"{fname}: expected {{size_bytes, tables}}")
    _check_type(fname, "top", "size_bytes", doc["size_bytes"], int)
    for cname, table in doc["tables"].items():
        if not isinstance(table, dict) or not table:
            raise SchemaError(f"{fname}: corpus {cname!r}: empty table")
        for algo, row in table.items():
            for m, sec in row.items():
                if not str(m).isdigit():
                    raise SchemaError(f"{fname}: {cname}/{algo}: bad length {m!r}")
                _check_type(fname, f"{cname}/{algo}/m={m}", "seconds", sec,
                            (int, float))


def format_paper_table(table: dict, title: str) -> str:
    """algo -> {m(str|int): seconds} grid, ms per pattern, best bold —
    the one renderer both benchmarks/run.py and the CI gate go through."""
    lengths = sorted({int(m) for row in table.values() for m in row})
    lines = [
        f"### {title}",
        "",
        "| algo | " + " | ".join(f"m={m}" for m in lengths) + " |",
        "|---|" + "---|" * len(lengths),
    ]
    best = {
        m: min(
            (float(row[k]) for row in table.values()
             for k in row if int(k) == m),
            default=float("inf"),
        )
        for m in lengths
    }
    for algo, row in table.items():
        by_m = {int(k): float(v) for k, v in row.items()}
        cells = []
        for m in lengths:
            v = by_m.get(m)
            if v is None:
                cells.append("-")
            else:
                s = f"{v * 1e3:.2f}"
                cells.append(f"**{s}**" if v == best[m] else s)
        lines.append(f"| {algo} | " + " | ".join(cells) + " |")
    lines += ["", "(ms per pattern, lower is better, best boldfaced)"]
    return "\n".join(lines)


def _derived_cols(fname: str):
    return [k for k in FILE_EXTRAS.get(fname, {}) if k not in ("P", "B", "m")]


def format_rows_table(fname: str, rows, meta=None) -> str:
    extras = _derived_cols(fname)
    # BENCH_stream rows carry family-specific ratio fields: surface whichever
    # each row has, in one "derived" column, so both families render.
    lines = [f"### {fname}", ""]
    if meta:
        lines += [
            "meta: " + "; ".join(f"{k}={meta[k]}" for k in sorted(meta)),
            "",
        ]
    lines += [
        "| name | µs/call | GB/s | MB | " + " | ".join(extras + ["derived"]) + " |",
        "|---|" + "---|" * (4 + len(extras)),
    ]
    known = set(ROW_REQUIRED) | set(FILE_EXTRAS.get(fname, {}))
    for row in rows:
        cells = [
            row["name"],
            f"{row['us_per_call']:.1f}",
            f"{row['GBps']:.3f}",
            f"{row['size_bytes'] / 1e6:.0f}",
        ]
        cells += [f"{row[k]}" for k in extras]
        derived = [
            f"{k}={row[k]}"
            for k in sorted(row)
            if k not in known and isinstance(row[k], (int, float))
            and not isinstance(row[k], bool)
        ]
        cells.append(";".join(derived) if derived else "-")
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines)


def render(outdir: Path) -> str:
    parts = [
        "# Benchmark trajectory (generated)",
        "",
        "Derived from the committed `BENCH_*.json` in this directory by",
        "`python benchmarks/render_tables.py` — edit the JSONs (via",
        "`python -m benchmarks.run`), never this file; CI's `benchgate` job",
        "regenerates it and fails on drift.  Numbers are developer-measured",
        "(XLA-CPU unless noted), NOT CI-measured.",
    ]
    paper = outdir / PAPER_JSON
    if paper.exists():
        doc = json.loads(paper.read_text())
        validate_paper(PAPER_JSON, doc)
        mb = doc["size_bytes"] / 1e6
        titles = {"genome": "Table 1", "protein": "Table 2", "english": "Table 3"}
        for cname, table in doc["tables"].items():
            t = titles.get(cname, "Table")
            parts += ["", format_paper_table(table, f"{t}: {cname} ({mb:.1f}MB)")]
    for f in sorted(outdir.glob("BENCH_*.json")):
        if f.name == PAPER_JSON:
            continue
        rows, meta = split_meta(f.name, json.loads(f.read_text()))
        validate_rows(f.name, rows)
        parts += ["", format_rows_table(f.name, rows, meta)]
    return "\n".join(parts) + "\n"


def write_markdown(outdir: Path) -> Path:
    md = outdir / MD_NAME
    md.write_text(render(outdir))
    return md


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/benchmarks")
    ap.add_argument(
        "--check", action="store_true",
        help="fail (exit 2) if the committed markdown differs from the "
        "regenerated one instead of rewriting it",
    )
    args = ap.parse_args(argv)
    outdir = Path(args.dir)
    try:
        text = render(outdir)
    except SchemaError as e:
        print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        return 1
    md = outdir / MD_NAME
    if args.check:
        have = md.read_text() if md.exists() else ""
        if have != text:
            print(
                f"{md} is stale: regenerate with "
                "`python benchmarks/render_tables.py`",
                file=sys.stderr,
            )
            return 2
        print(f"{md} is in sync with the committed JSONs")
        return 0
    md.write_text(text)
    print(f"wrote {md}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
