"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
JSON records in experiments/dryrun/."""

from __future__ import annotations

import json
from pathlib import Path
from typing import List

DRYRUN_DIR = Path("experiments/dryrun")


def load_records(dryrun_dir=DRYRUN_DIR) -> List[dict]:
    recs = []
    for f in sorted(Path(dryrun_dir).glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def markdown_table(recs: List[dict], mesh: str = "16x16") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    hdr = (
        "| arch | shape | kind | compute s | memory s | collective s | "
        "bottleneck | MODEL/HLO | step bound s |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        t = r["roofline"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind'].split('_',1)[1]} "
            f"| {t['compute_s']:.3g} | {t['memory_s']:.3g} "
            f"| {t['collective_s']:.3g} | {t['bottleneck']} "
            f"| {ratio:.2f} | {bound:.3g} |"
            if ratio is not None
            else f"| {r['arch']} | {r['shape']} | {r['kind']} | - | - | - | - | - | - |"
        )
    return "\n".join(lines)


def summary(recs: List[dict]) -> str:
    lines = []
    for mesh in ("16x16", "2x16x16"):
        rows = [r for r in recs if r["mesh"] == mesh]
        if not rows:
            continue
        by_bneck = {}
        for r in rows:
            by_bneck.setdefault(r["roofline"]["bottleneck"], []).append(r)
        lines.append(
            f"mesh {mesh}: {len(rows)} cells — "
            + ", ".join(f"{k}-bound: {len(v)}" for k, v in sorted(by_bneck.items()))
        )
    return "\n".join(lines)


def main():
    recs = load_records()
    print(summary(recs))
    print()
    print(markdown_table(recs, "16x16"))


if __name__ == "__main__":
    main()
