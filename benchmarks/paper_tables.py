"""Paper Tables 1-3: running time of EPSM vs the best known algorithms for
short patterns on a genome sequence, a protein sequence and a natural
language text (the paper uses 4MB texts, 1000 patterns per length,
m in {2,...,32}; defaults here are scaled for CPU CI — pass full=True for
paper-scale).

Caveat recorded in EXPERIMENTS.md: the paper measures SSE4.2 hardware; we
measure the TPU-adapted algorithms under XLA-CPU, so absolute numbers differ
but the claim under test is the RELATIVE ordering (packed filters beat
character-at-a-time scanning for short patterns).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax

from repro.core import baselines, epsm
from repro.data import corpus

ALGOS = {
    "EPSM": lambda t, p: epsm.find(t, p, algo="auto"),
    "EPSMa": lambda t, p: epsm.find(t, p, algo="epsma"),
    "EPSMb": lambda t, p: epsm.find(t, p, algo="epsmb"),
    "EPSMc": lambda t, p: epsm.find(t, p, algo="epsmc"),
    "PackedNaive": baselines.packed_naive,
    "SO": baselines.shift_or,
    "KMP": baselines.kmp_dfa,
    "RK": baselines.rabin_karp,
    "HASH3": baselines.hash3,
    "BNDM": baselines.bndm,
}

DEFAULT_M = (2, 4, 8, 12, 16, 24, 32)
FULL_M = (2, 4, 6, 8, 12, 16, 20, 24, 28, 32)


def _time_one(fn, t, p, reps=3) -> float:
    # close over the concrete pattern: skip-based baselines (kmp/hash3/bndm)
    # build their tables in host preprocessing, exactly as real impls do;
    # timing covers the compiled search phase.
    jfn = jax.jit(lambda tt: fn(tt, p))
    mask = jfn(t)
    mask.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        jfn(t).block_until_ready()
    return (time.perf_counter() - t0) / reps


def run_table(
    corpus_name: str,
    *,
    size: int = 1_000_000,
    lengths=DEFAULT_M,
    n_patterns: int = 3,
    algos=None,
    verify: bool = True,
) -> Dict[str, Dict[int, float]]:
    """Returns algo -> {m: seconds per pattern}; verifies exactness on the way."""
    text = corpus.make_corpus(corpus_name, size, seed=0)
    results: Dict[str, Dict[int, float]] = {}
    chosen = algos or list(ALGOS)
    for m in lengths:
        pats = corpus.extract_patterns(text, m, n_patterns, seed=m)
        oracle = None
        for name in chosen:
            fn = ALGOS[name]
            if name == "BNDM" and m > 31:
                continue
            if name == "HASH3" and m < 3:
                continue
            times = []
            for i, p in enumerate(pats):
                times.append(_time_one(fn, text, p))
                if verify and i == 0:
                    got = np.asarray(fn(text, p))
                    if oracle is None:
                        oracle = got  # first algo defines; all must agree
                    else:
                        assert np.array_equal(got, oracle), (name, m)
            results.setdefault(name, {})[m] = float(np.mean(times))
    return results


def format_table(results: Dict[str, Dict[int, float]], title: str) -> str:
    """Delegates to the ONE grid renderer (benchmarks/render_tables.py) the
    CI benchgate drift check also runs — interactive callers and the gate
    can't format the same data two ways."""
    from benchmarks.render_tables import format_paper_table

    return format_paper_table(results, title)


def table_genome(**kw):
    return run_table("genome", **kw)


def table_protein(**kw):
    return run_table("protein", **kw)


def table_english(**kw):
    return run_table("english", **kw)
