"""Benchmark harness entry: one function per paper table + framework benches.

    PYTHONPATH=src python -m benchmarks.run [--full] [--size N]

Prints ``name,us_per_call,derived`` CSV rows and writes the markdown tables
under experiments/benchmarks/.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import numpy as np


def _emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


# label -> warmup (first-call) duration in ms, drained into each bench's
# BENCH json meta as "compile_ms".  The warmup call pays jit tracing +
# compilation; timing it separately keeps compile time OUT of the GB/s rows
# (previously visible as noisy first rows on cold caches) while still
# recording it.
_COMPILE_MS: dict = {}


def drain_compile_ms() -> dict:
    """The warmup durations recorded since the last drain (label -> ms,
    sorted), cleared — each bench calls this once when writing its meta."""
    out = {k: round(_COMPILE_MS[k], 1) for k in sorted(_COMPILE_MS)}
    _COMPILE_MS.clear()
    return out


def timeit_median(fn, *args, reps: int = 7, label: str = None) -> float:
    """Median wall-time of fn(*args) after one warmup call, blocking on the
    result each rep (the ONE timing helper every bench below uses).  The
    warmup call — where jit compilation lands — is timed separately and
    recorded under ``label`` for :func:`drain_compile_ms`; it is never part
    of the returned median."""
    import jax

    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    if label is not None:
        _COMPILE_MS[label] = (time.perf_counter() - t0) * 1e3
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_paper_tables(size: int, full: bool, outdir: Path):
    """Times the paper's Tables 1-3 and records them as machine-readable
    BENCH_paper_tables.json — the markdown is rendered from that JSON by
    benchmarks/render_tables.py (the same module CI's drift gate runs), so
    the committed tables can never disagree with the committed data."""
    import json

    from benchmarks import paper_tables as pt

    lengths = pt.FULL_M if full else pt.DEFAULT_M
    tables = {}
    for table_fn, cname in (
        (pt.table_genome, "genome"),
        (pt.table_protein, "protein"),
        (pt.table_english, "english"),
    ):
        res = table_fn(size=size, lengths=lengths, n_patterns=2)
        tables[cname] = {
            algo: {str(m): sec for m, sec in row.items()}
            for algo, row in res.items()
        }
        for algo, row in res.items():
            for m, sec in row.items():
                _emit(f"paper/{cname}/{algo}/m{m}", sec * 1e6,
                      f"GBps={size/sec/1e9:.3f}")
    (outdir / "BENCH_paper_tables.json").write_text(
        json.dumps({"size_bytes": size, "tables": tables}, indent=1)
    )


def bench_kernels(size: int, outdir: Path):
    """Pallas kernels (interpret mode = correctness surface) vs pure-JAX core.

    interpret=True executes the kernel body in Python, so wall-time is NOT
    meaningful on CPU; we emit the pure-JAX packed-core timing as the
    executable proxy and record kernel/oracle agreement."""
    import jax

    from repro.core import epsm
    from repro.data import corpus
    from repro.kernels.epsma import epsma as k_epsma
    from repro.kernels.epsmb import epsmb as k_epsmb
    from repro.kernels.epsmc import epsmc as k_epsmc

    from repro.kernels.multipattern import multipattern as k_mp

    text = corpus.make_corpus("english", min(size, 200_000), seed=0)
    pats = corpus.extract_patterns(text, 8, 4, seed=9)
    mp_ok = np.array_equal(
        np.asarray(k_mp(text, pats)),
        np.stack([np.asarray(epsm.find(text, p)) for p in pats]),
    )
    _emit("kernel/multipattern_p4", 0.0, f"interpret_matches_core={mp_ok}")
    for name, kfn, m in (
        ("epsma", k_epsma, 3),
        ("epsmb", k_epsmb, 8),
        ("epsmc", k_epsmc, 24),
    ):
        p = corpus.extract_patterns(text, m, 1, seed=m)[0]
        got = np.asarray(kfn(text, p))
        want = np.asarray(epsm.find(text, p))
        ok = np.array_equal(got, want)
        jfn = jax.jit(lambda t, pp: epsm.find(t, pp))
        jfn(text, p).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            jfn(text, p).block_until_ready()
        dt = (time.perf_counter() - t0) / 3
        _emit(f"kernel/{name}", dt * 1e6, f"interpret_matches_core={ok}")


def bench_multipattern(size: int, outdir: Path):
    """Shared-text engine vs the seed vmap path, machine-readable trajectory.

    Writes BENCH_multipattern.json rows {name, us_per_call, GBps, P, B,
    speedup_vs_vmap} so future PRs can diff throughput.  The workload is the
    seed bench's: per-pattern occurrence counts of P length-8 patterns
    extracted from a `size`-byte english corpus (counts are what the
    pipeline/serving consumers reduce to; the engine never materializes the
    (B, P, n) mask for them)."""
    import json

    import jax
    import jax.numpy as jnp

    from repro.core import engine as eng
    from repro.core.multipattern import count_multi_vmap
    from repro.data import corpus

    text = corpus.make_corpus("english", size, seed=0)
    tj = jnp.asarray(text)
    rows = []
    for npat in (1, 8, 32):
        pats = corpus.extract_patterns(text, 8, npat, seed=5)
        pj = jnp.asarray(pats)
        f_vmap = jax.jit(count_multi_vmap)
        plans = eng.compile_patterns(list(pats))
        f_eng = jax.jit(lambda t, plans=plans: eng.count_many(eng.build_index(t), plans))
        assert np.array_equal(
            np.asarray(f_eng(tj))[0], np.asarray(f_vmap(tj, pj))
        ), "engine/vmap count divergence"
        dt_v = timeit_median(f_vmap, tj, pj,
                             label=f"multipattern/vmap_baseline/p{npat}")
        dt_e = timeit_median(f_eng, tj, label=f"multipattern/engine/p{npat}")
        for name, dt, speedup in (
            (f"multipattern/vmap_baseline/p{npat}", dt_v, 1.0),
            (f"multipattern/engine/p{npat}", dt_e, dt_v / dt_e),
        ):
            rows.append({
                "name": name,
                "us_per_call": dt * 1e6,
                "GBps": size / dt / 1e9,
                "GBps_effective": size * npat / dt / 1e9,
                "P": npat,
                "B": 1,
                "m": 8,
                "size_bytes": size,
                "speedup_vs_vmap": round(speedup, 3),
            })
            _emit(name, dt * 1e6,
                  f"GBps_eff={size*npat/dt/1e9:.3f};speedup={speedup:.2f}x")
    # experiments/benchmarks/ is the ONE canonical location for bench
    # artifacts (the repo-root copy this used to also write is gone)
    (outdir / "BENCH_multipattern.json").write_text(
        json.dumps({"meta": {"compile_ms": drain_compile_ms()}, "rows": rows},
                   indent=1)
    )


def make_adversarial_text(pats: np.ndarray, n: int) -> np.ndarray:
    """Worst-case texture for the union-LUT gate: the dictionary tiled end
    to end.  Every pattern-aligned window probes a REGISTERED fingerprint,
    so candidate density saturates (the measured union-block count hits the
    total) — the texture that must reroute to the bounded fallback
    (automaton / slot-dense CSR) instead of melting the sparse gather.
    Deterministic: a pure function of the dictionary."""
    flat = pats.reshape(-1)
    reps = -(-n // flat.size) + 1
    return np.tile(flat, reps)[:n].copy()


def _dict_reference_counts(text: np.ndarray, pats: np.ndarray) -> np.ndarray:
    """Exact numpy occurrence counts for a (P, 8) dictionary via the u64
    window view — O(n log n), feasible at P = 50k where the naive per-
    pattern scan is not."""
    win = np.lib.stride_tricks.sliding_window_view(text, pats.shape[1])
    w64 = np.ascontiguousarray(win).view(np.uint64)[:, 0]
    p64 = np.ascontiguousarray(pats).view(np.uint64)[:, 0]
    uniq, cnt = np.unique(w64, return_counts=True)
    pos = np.minimum(np.searchsorted(uniq, p64), len(uniq) - 1)
    return np.where(uniq[pos] == p64, cnt[pos], 0).astype(np.int32)


def bench_dictionary(outdir: Path):
    """Dictionary-scale matching (DESIGN.md §14): P x texture grid.

    One dispatch answers P patterns against a 1 MB text for
    P in {32, 1k, 10k, 50k}, on an average (random + planted) texture and
    the adversarial tiled-dictionary texture.  Writes BENCH_dictionary.json
    rows {name, us_per_call, GBps, P, texture, route, ratio_vs_avg,
    plan_build_ms}: ``route`` is what engine.route_probe measured for that
    (text, plans) pair, ``ratio_vs_avg`` is the adversarial slowdown
    against the same-P average row (the <= 5x acceptance bound), and
    ``plan_build_ms`` is the recorded plan_compile span (repro.obs).
    Every measured count is cross-checked against an exact numpy u64
    reference before timing."""
    import json

    import jax
    import jax.numpy as jnp

    from repro.core import engine as eng
    from repro.obs.recorder import Recorder

    n, m = 1_000_000, 8
    rng = np.random.default_rng(0xD1C7)
    rows = []
    for P in (32, 1_000, 10_000, 50_000):
        pats = np.unique(
            rng.integers(0, 256, size=(2 * P, m), dtype=np.uint8), axis=0
        )
        rng.shuffle(pats)
        pats = pats[:P]
        rec = Recorder(fence=False)
        t0 = time.perf_counter()
        # bucket=True at every P: the sweep measures the dictionary-scale
        # machinery itself (tests/test_dictionary.py pins it bit-identical
        # to the flat plans), so even the P=32 row gets the bounded CSR
        # routes instead of the flat dense fallback on the flood texture
        plans = eng.compile_patterns(
            [p for p in pats], bucket=True, recorder=rec
        )
        plan_ms = (time.perf_counter() - t0) * 1e3

        avg = rng.integers(0, 256, size=n, dtype=np.uint8)
        for i in range(0, P, max(1, P // 37)):
            pos = (i * 8191) % (n - m)
            avg[pos : pos + m] = pats[i]
        adv = make_adversarial_text(pats, n)

        f = jax.jit(
            lambda t, plans=plans: eng.count_many(eng.build_index(t), plans)
        )
        order = eng.plan_order(plans)
        base_dt = None
        for texture, text in (("average", avg), ("adversarial", adv)):
            idx = eng.build_index(text)
            info = eng.route_probe(idx, plans, recorder=rec)
            tj = jnp.asarray(text)
            got = np.asarray(f(tj))[0]
            want = _dict_reference_counts(text, pats)[order]
            assert np.array_equal(got, want), (
                f"dictionary count divergence at P={P} texture={texture}"
            )
            dt = timeit_median(
                f, tj, label=f"dictionary/{texture}/p{P}"
            )
            if texture == "average":
                base_dt = dt
            ratio = dt / base_dt
            rows.append({
                "name": f"dictionary/{texture}/p{P}",
                "us_per_call": dt * 1e6,
                "GBps": n / dt / 1e9,
                "size_bytes": n,
                "P": P,
                "m": m,
                "texture": texture,
                "route": str(info["route"]),
                "ratio_vs_avg": round(ratio, 3),
                "plan_build_ms": round(plan_ms, 1),
                "matches": int(got.sum()),
            })
            _emit(
                f"dictionary/{texture}/p{P}", dt * 1e6,
                f"route={info['route']};ratio={ratio:.2f}x;"
                f"plan_ms={plan_ms:.0f}",
            )
    (outdir / "BENCH_dictionary.json").write_text(
        json.dumps({"meta": {"compile_ms": drain_compile_ms()}, "rows": rows},
                   indent=1)
    )


def bench_approx(size: int, outdir: Path):
    """k-mismatch engine (repro.approx) vs the exact path, machine-readable.

    Writes BENCH_approx.json rows {name, us_per_call, GBps, m, k,
    ratio_vs_exact} for m in {4, 8, 16} x k in {0, 1, 2} over a `size`-byte
    english corpus (per-pattern counts, the reduced hot path).  Counts are
    cross-checked against the naive k-mismatch reference before timing."""
    import json

    import jax
    import jax.numpy as jnp

    from repro.approx import kmismatch_naive
    from repro.core import engine as eng
    from repro.data import corpus

    text = corpus.make_corpus("english", size, seed=0)
    tj = jnp.asarray(text)
    rows = []
    for m in (4, 8, 16):
        pats = corpus.extract_patterns(text, m, 1, seed=5)
        dt_exact = None
        for k in (0, 1, 2):
            plans = eng.compile_patterns(list(pats), k=k)
            f = jax.jit(
                lambda t, plans=plans, k=k: eng.count_many(
                    eng.build_index(t), plans, k=k
                )
            )
            want = int(kmismatch_naive(text, pats[0], k).sum())
            got = int(np.asarray(f(tj))[0, 0])
            assert got == want, f"approx/naive divergence m={m} k={k}"
            dt = timeit_median(f, tj, label=f"approx/m{m}/k{k}")
            if k == 0:
                dt_exact = dt
            ratio = dt / dt_exact
            rows.append({
                "name": f"approx/m{m}/k{k}",
                "us_per_call": dt * 1e6,
                "GBps": size / dt / 1e9,
                "m": m,
                "k": k,
                "P": 1,
                "B": 1,
                "size_bytes": size,
                "occurrences": got,
                "ratio_vs_exact": round(ratio, 3),
                "relaxed_lut_compiled": plans[0].relaxed_lut is not None,
            })
            _emit(f"approx/m{m}/k{k}", dt * 1e6,
                  f"GBps={size/dt/1e9:.3f};vs_exact={ratio:.2f}x")
    (outdir / "BENCH_approx.json").write_text(
        json.dumps({"meta": {"compile_ms": drain_compile_ms()}, "rows": rows},
                   indent=1)
    )


def bench_stream(outdir: Path):
    """Streaming scan engine vs resident whole-text dispatch, plus the
    shared-fingerprint multi-group count vs the per-group-pass baseline.

    Writes BENCH_stream.json.  Two row families:

      * stream/{resident,scanner}/<MB>mb — per-pattern counts of 8 length-8
        patterns over a genome corpus at 16/64/256 MB, timed END TO END from
        a host buffer (device_put + one dispatch for resident; chunked
        double-buffered scan for the scanner).  Rows carry the estimated
        peak device bytes: resident materializes the ~9.5 byte/byte index,
        the scanner O(chunk_bytes).  ``ratio_vs_resident`` is scanner GBps /
        resident GBps.

      * stream/fp_{pergroup_baseline,shared}/3groups — resident count_many
        over 3 EPSMb length groups (m = 8/12/15, P = 8 each, 32 MB): one
        jitted dispatch per group (each paying its own fingerprint pass and
        candidate compaction — the pre-stream engine shape) vs the single
        shared-substrate dispatch (_count_groups_b_shared).  Counts are
        cross-checked before timing.
    """
    import json

    import jax

    from repro.core import engine as eng
    from repro.core.stream import StreamScanner
    from repro.data import corpus

    rows = []
    chunk = 1 << 22

    # -- streaming vs resident ---------------------------------------------
    for mb in (16, 64, 256):
        size = mb * 1_000_000
        text = corpus.make_corpus("genome", size, seed=0)
        pats = [text[i * 1009 : i * 1009 + 8].copy() for i in range(8)]
        plans = eng.compile_patterns(list(pats))

        f_res = jax.jit(lambda t: eng.count_many(eng.build_index(t), plans))

        def resident(th=text, f=f_res):
            return f(jax.device_put(th))

        sc = StreamScanner(plans, chunk)
        sc.count_many(text[: 2 * sc.window_bytes])  # warm the per-shape trace

        def streamed(th=text, s=sc):
            return s.count_many(th)

        assert np.array_equal(streamed(), np.asarray(resident())[0]), (
            f"stream/resident divergence at {mb} MB"
        )
        dt_r = timeit_median(resident, reps=3,
                             label=f"stream/resident/{mb}mb")
        dt_s = timeit_median(streamed, reps=3,
                             label=f"stream/scanner/{mb}mb")
        res_dev = int(9.5 * size)  # text + packed + block_fp + fp temporary
        for name, dt, dev in (
            (f"stream/resident/{mb}mb", dt_r, res_dev),
            (f"stream/scanner/{mb}mb", dt_s, sc.device_bytes_per_chunk),
        ):
            rows.append({
                "name": name,
                "us_per_call": dt * 1e6,
                "GBps": size / dt / 1e9,
                "P": 8,
                "m": 8,
                "size_bytes": size,
                "chunk_bytes": chunk,
                "peak_device_bytes": dev,
                "ratio_vs_resident": round(dt_r / dt, 3),
            })
            _emit(name, dt * 1e6,
                  f"GBps={size/dt/1e9:.3f};vs_resident={dt_r/dt:.2f}x;"
                  f"dev_bytes={dev}")

    # -- shared fingerprint pass vs per-group passes ------------------------
    size = 32_000_000
    text = corpus.make_corpus("genome", size, seed=0)
    pats = []
    for m in (8, 12, 15):
        pats += [text[i * 997 + m : i * 997 + 2 * m].copy() for i in range(8)]
    plans = eng.compile_patterns(pats)
    idx = jax.tree_util.tree_map(
        jax.device_put, eng.build_index(jax.device_put(text))
    )
    f_shared = jax.jit(lambda i: eng.count_many(i, plans))
    f_per = [jax.jit(lambda i, p=p: eng.count_many(i, (p,))) for p in plans]
    got = np.asarray(f_shared(idx))[0]
    want = np.concatenate([np.asarray(f(idx))[0] for f in f_per])
    assert np.array_equal(got, want), "shared/per-group count divergence"
    dt_shared = timeit_median(f_shared, idx, reps=5,
                              label="stream/fp_shared/3groups")
    dt_per = sum(
        timeit_median(f, idx, reps=5,
                      label=f"stream/fp_pergroup_baseline/3groups/g{gi}")
        for gi, f in enumerate(f_per)
    )
    for name, dt in (
        ("stream/fp_pergroup_baseline/3groups", dt_per),
        ("stream/fp_shared/3groups", dt_shared),
    ):
        rows.append({
            "name": name,
            "us_per_call": dt * 1e6,
            "GBps": size / dt / 1e9,
            "P": len(pats),
            "groups": 3,
            "size_bytes": size,
            "speedup_vs_pergroup": round(dt_per / dt, 3),
        })
        _emit(name, dt * 1e6,
              f"GBps={size/dt/1e9:.3f};vs_pergroup={dt_per/dt:.2f}x")
    (outdir / "BENCH_stream.json").write_text(
        json.dumps({"meta": {"compile_ms": drain_compile_ms()}, "rows": rows},
                   indent=1)
    )


def bench_megascan(outdir: Path):
    """Fused one-dispatch streaming vs the per-group baseline — the
    megakernel PR's acceptance artifact (BENCH_megascan.json).

    The fused path is one StreamScanner per plan set: ONE dispatch per chunk
    answers every length group (shared fingerprint bank + shared candidate
    compaction) with the seam correction folded in (count_many end_min).
    The baseline is the pre-fusion shape: one StreamScanner per length
    group with fused=False, shared=False — each group re-scans the stream
    through its own per-group matcher (count_many shared=False, the
    _COUNT dispatch that remains the engine's fallback path), paying its own
    fingerprint pass, candidate compaction, and two-pass overlap-prefix seam
    subtraction over the same bytes.  Pallas
    interpret-mode wall-time is not meaningful on CPU (see bench_kernels),
    so the timed fused path is the pure-JAX engine the kernel is pinned
    bit-identical to by tests/test_megascan.py — the established executable
    proxy.  Grid: {16, 64, 256} MB x {1, 3, 5} length groups x k in {0, 1},
    4 patterns per group; counts are cross-checked before timing."""
    import json
    import os

    from repro.core import engine as eng
    from repro.core.stream import StreamScanner
    from repro.data import corpus
    from repro.kernels.megascan import build_mega_spec

    GROUP_MS = {1: (8,), 3: (8, 12, 15), 5: (2, 5, 12, 16, 24)}
    npat = 4
    chunk = 1 << 22
    rows = []
    for mb in (16, 64, 256):
        size = mb * 1_000_000
        text = corpus.make_corpus("genome", size, seed=0)
        for g, ms in GROUP_MS.items():
            pats = []
            for m in ms:
                pats += [
                    text[i * 997 + m : i * 997 + 2 * m].copy()
                    for i in range(npat)
                ]
            for k in (0, 1):
                plans = eng.compile_patterns(pats, k=k)
                assert build_mega_spec(plans, k=k) is not None, (
                    f"plan set unexpectedly kernel-ineligible g={g} k={k}"
                )
                fused_sc = StreamScanner(plans, chunk, k=k)
                per_scs = [
                    StreamScanner((p,), chunk, k=k, fused=False, shared=False)
                    for p in plans
                ]
                warm = text[: 2 * fused_sc.window_bytes]
                fused_sc.count_many(warm)
                for s in per_scs:
                    s.count_many(warm)
                got = fused_sc.count_many(text)
                want = np.concatenate(
                    [s.count_many(text) for s in per_scs]
                )
                assert np.array_equal(got, want), (
                    f"fused/per-group divergence mb={mb} g={g} k={k}"
                )
                dt_f = timeit_median(
                    lambda s=fused_sc: s.count_many(text), reps=3,
                    label=f"megascan/fused/{mb}mb/g{g}/k{k}",
                )
                dt_p = sum(
                    timeit_median(
                        lambda s=s: s.count_many(text), reps=3,
                        label=f"megascan/pergroup/{mb}mb/g{g}/k{k}/{gi}",
                    )
                    for gi, s in enumerate(per_scs)
                )
                for name, dt, speedup in (
                    (f"megascan/pergroup_baseline/{mb}mb/g{g}/k{k}", dt_p, 1.0),
                    (f"megascan/fused/{mb}mb/g{g}/k{k}", dt_f, dt_p / dt_f),
                ):
                    rows.append({
                        "name": name,
                        "us_per_call": dt * 1e6,
                        "GBps": size / dt / 1e9,
                        "size_bytes": size,
                        "chunk_bytes": chunk,
                        "groups": g,
                        "P": npat * g,
                        "k": k,
                        "speedup_vs_pergroup": round(speedup, 3),
                    })
                    _emit(name, dt * 1e6,
                          f"GBps={size/dt/1e9:.3f};vs_pergroup={speedup:.2f}x")
    meta = {
        "host_cores": os.cpu_count(),
        "baseline": "one StreamScanner(fused=False, shared=False) per length "
                    "group (per-group fingerprint pass + per-group "
                    "compaction + two-pass seam)",
        "fused": "one StreamScanner: single dispatch per chunk, all groups, "
                 "seam folded in (megakernel executable proxy; kernel pinned "
                 "bit-identical by tests/test_megascan.py)",
        "compile_ms": drain_compile_ms(),
    }
    (outdir / "BENCH_megascan.json").write_text(
        json.dumps({"meta": meta, "rows": rows}, indent=1)
    )


def _bench_shard_child(outpath: str):
    """Runs INSIDE the 8-forced-host-device subprocess bench_shard spawns:
    times ShardedStreamScanner at 64 MB for shard counts {1, 2, 4, 8} vs the
    1-shard StreamScanner baseline, cross-checking counts first, and writes
    the BENCH_shard.json rows."""
    import json
    import os

    import jax

    from repro.core import engine as eng
    from repro.core.shard_stream import ShardedStreamScanner
    from repro.core.stream import StreamScanner
    from repro.data import corpus

    size = 64_000_000
    chunk = 1 << 22
    ndev = len(jax.devices())
    text = corpus.make_corpus("genome", size, seed=0)
    pats = [text[i * 1009 : i * 1009 + 8].copy() for i in range(8)]
    plans = eng.compile_patterns(list(pats))

    base_sc = StreamScanner(plans, chunk)
    base_sc.count_many(text[: 2 * base_sc.window_bytes])  # warm the trace
    want = base_sc.count_many(text)

    def run_base():
        return StreamScanner(plans, chunk).count_many(text)

    dt_1 = timeit_median(run_base, reps=3, label="shard/stream_baseline/64mb")
    rows = [{
        "name": "shard/stream_baseline/64mb",
        "us_per_call": dt_1 * 1e6,
        "GBps": size / dt_1 / 1e9,
        "size_bytes": size,
        "chunk_bytes": chunk,
        "shards": 1,
        "devices": ndev,
        "speedup_vs_1shard": 1.0,
    }]
    for S in (1, 2, 4, 8):
        sc = ShardedStreamScanner(plans, S, chunk)
        got = sc.count_many(text)
        assert np.array_equal(got, want), f"sharded/baseline divergence S={S}"

        def run_sharded(S=S):
            return ShardedStreamScanner(plans, S, chunk).count_many(text)

        dt = timeit_median(run_sharded, reps=3, label=f"shard/sharded_{S}/64mb")
        rows.append({
            "name": f"shard/sharded_{S}/64mb",
            "us_per_call": dt * 1e6,
            "GBps": size / dt / 1e9,
            "size_bytes": size,
            "chunk_bytes": chunk,
            "shards": S,
            "devices": ndev,
            "speedup_vs_1shard": round(dt_1 / dt, 3),
        })
    meta = {
        # per ROADMAP: 8 forced host devices time-slice the physical cores,
        # so shard scaling here is pipeline overlap, not linear core scaling
        "host_cores": os.cpu_count(),
        "forced_devices": ndev,
        "baseline": "fused StreamScanner (one dispatch per chunk, "
                    "count_many end_min seam)",
        "compile_ms": drain_compile_ms(),
    }
    Path(outpath).write_text(json.dumps({"meta": meta, "rows": rows}, indent=1))


def bench_shard(outdir: Path):
    """Sharded streaming vs 1-shard streaming at 64 MB (BENCH_shard.json).

    Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_
    count=8 (device count locks at first jax init, and the whole point is
    per-shard device placement): shards round-robin over the 8 host devices
    and their async dispatch queues drain concurrently, so the wall-clock
    scaling measured here is the real multi-device pipeline, CPU-backed."""
    import json
    import os
    import subprocess
    import sys

    out = outdir / "BENCH_shard.json"
    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "JAX_PLATFORMS": "cpu",
        "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
    }
    res = subprocess.run(
        [
            sys.executable, "-c",
            "import sys; sys.path.insert(0, '.'); "
            "from benchmarks.run import _bench_shard_child; "
            "_bench_shard_child(sys.argv[1])",
            str(out),
        ],
        env=env,
        timeout=3600,
    )
    if res.returncode != 0:
        raise RuntimeError("bench_shard subprocess failed")
    for row in json.loads(out.read_text())["rows"]:
        _emit(row["name"], row["us_per_call"],
              f"GBps={row['GBps']:.3f};shards={row['shards']};"
              f"vs_1shard={row['speedup_vs_1shard']:.2f}x")


def _bench_faults_child(outpath: str):
    """Runs INSIDE the 8-forced-host-device subprocess bench_faults spawns:
    times the sharded scan at 16 MB clean vs under a 5%-per-site fault rate
    (read errors + truncations + shard crashes, transient, recovered by
    retry), on both the static and the work-stealing path, cross-checking
    every configuration against the clean StreamScanner first.  Writes
    BENCH_faults.json; ``ratio_vs_clean`` is throughput relative to the
    static clean run — the recovery overhead the chaos CI job tracks."""
    import json
    import os

    import jax

    from repro.core import engine as eng
    from repro.core.shard_stream import ShardedStreamScanner
    from repro.core.stream import StreamScanner
    from repro.data import corpus
    from repro.dist.fault_injection import FaultPlan, FaultyRangeSource
    from repro.dist.fault_tolerance import BackoffPolicy

    size = 16_000_000
    chunk = 1 << 22
    S = 8
    RATE = 0.05
    ndev = len(jax.devices())
    text = corpus.make_corpus("genome", size, seed=0)
    pats = [text[i * 1009 : i * 1009 + 8].copy() for i in range(8)]
    plans = eng.compile_patterns(list(pats))

    base_sc = StreamScanner(plans, chunk)
    base_sc.count_many(text[: 2 * base_sc.window_bytes])  # warm the trace
    want = base_sc.count_many(text)

    def make_plan():
        # fresh plan per run: per-key heal counters reset, so every rep
        # injects the identical fault schedule
        return FaultPlan(
            0, read_error_rate=RATE, truncate_rate=RATE, crash_rate=RATE,
            attempts_per_fault=1,
        )

    def run(steal: bool, faulty: bool):
        plan = make_plan() if faulty else None
        sc = ShardedStreamScanner(
            plans, S, chunk, max_retries=16, fault_plan=plan, steal=steal,
            backoff=BackoffPolicy(base_s=0.0, jitter=0.0),
        )
        src = FaultyRangeSource(text, plan) if faulty else text
        return sc.count_many(src), sc

    configs = [
        ("faults/static_clean/16mb", False, False),
        ("faults/static_faulty5pct/16mb", False, True),
        ("faults/steal_clean/16mb", True, False),
        ("faults/steal_faulty5pct/16mb", True, True),
    ]
    observed = {}
    for name, steal, faulty in configs:
        got, sc = run(steal, faulty)
        assert np.array_equal(got, want), f"{name}: faulted scan diverged"
        observed[name] = {"retries": len(sc.events), "steals": len(sc.steal_events)}

    times = {
        name: timeit_median(lambda s=steal, f=faulty: run(s, f)[0], reps=3,
                            label=name)
        for name, steal, faulty in configs
    }
    dt_clean = times["faults/static_clean/16mb"]
    rows = []
    for name, steal, faulty in configs:
        dt = times[name]
        rows.append({
            "name": name,
            "us_per_call": dt * 1e6,
            "GBps": size / dt / 1e9,
            "size_bytes": size,
            "chunk_bytes": chunk,
            "shards": S,
            "devices": ndev,
            "fault_rate": RATE if faulty else 0.0,
            "retries": observed[name]["retries"],
            "steals": observed[name]["steals"],
            "ratio_vs_clean": round(dt_clean / dt, 3),
        })
        _emit(name, dt * 1e6,
              f"GBps={size/dt/1e9:.3f};vs_clean={dt_clean/dt:.2f}x;"
              f"retries={observed[name]['retries']}")
    meta = {
        "host_cores": os.cpu_count(),
        "forced_devices": ndev,
        "fault_model": "FaultPlan(seed=0): 5% read errors + 5% truncations "
                       "+ 5% shard crashes per site, transient "
                       "(attempts_per_fault=1), zero-delay backoff",
        "baseline": "static_clean (no faults, no stealing); ratio_vs_clean "
                    "= its wall-time / this row's",
        "compile_ms": drain_compile_ms(),
    }
    Path(outpath).write_text(json.dumps({"meta": meta, "rows": rows}, indent=1))


def bench_faults(outdir: Path):
    """Fault-recovery overhead bench (BENCH_faults.json): clean vs 5%-fault
    sharded scans, static vs work-stealing, in a subprocess with
    XLA_FLAGS=--xla_force_host_platform_device_count=8 (same reasoning as
    bench_shard: device count locks at first jax init)."""
    import json
    import os
    import subprocess
    import sys

    out = outdir / "BENCH_faults.json"
    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "JAX_PLATFORMS": "cpu",
        "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
    }
    res = subprocess.run(
        [
            sys.executable, "-c",
            "import sys; sys.path.insert(0, '.'); "
            "from benchmarks.run import _bench_faults_child; "
            "_bench_faults_child(sys.argv[1])",
            str(out),
        ],
        env=env,
        timeout=3600,
    )
    if res.returncode != 0:
        raise RuntimeError("bench_faults subprocess failed")
    for row in json.loads(out.read_text())["rows"]:
        _emit(row["name"], row["us_per_call"],
              f"GBps={row['GBps']:.3f};fault_rate={row['fault_rate']};"
              f"vs_clean={row['ratio_vs_clean']:.2f}x")


def _bench_obs_child(outpath: str):
    """Runs INSIDE the 8-forced-host-device subprocess bench_obs spawns.

    Two row families (BENCH_obs.json):

      * obs/{none,disabled,traced}/<MB>mb — streaming scan throughput at
        16/64 MB with (none) the module-default recorder, (disabled) an
        explicitly attached ``Recorder(enabled=False)``, and (traced) a full
        tracing recorder with fenced dispatches.  The scan code calls the
        recorder unconditionally — no ``if tracing:`` forks — so
        none vs disabled measures the cost of that design: the acceptance
        budget is disabled overhead_pct < 2 at 64 MB.  traced pays fencing
        (per-dispatch sync, pipeline serialized) — the honest cost of
        attribution, reported, not hidden.

      * obs/shard_split/s{S}/64mb — ONE traced ``ShardedStreamScanner`` run
        per shard count S in {1, 2, 4, 8}: the recorder's span totals give
        the first honest host_prep vs device_put vs dispatch wall-time
        split.  On this 1-core/8-forced-device box host_prep + dispatch
        both burn the same physical core regardless of S — the measured
        explanation for BENCH_shard.json's flat ~1.0x curve.

    The S=8 run's Perfetto trace is exported next to the JSON
    (obs_shard8_trace.json) and schema-checked by
    benchmarks/validate_trace.py before it is written."""
    import json
    import os

    import jax

    from benchmarks.validate_trace import validate_trace
    from repro.core import engine as eng
    from repro.core.shard_stream import ShardedStreamScanner
    from repro.core.stream import StreamScanner
    from repro.data import corpus
    from repro.obs import Recorder

    chunk = 1 << 22
    ndev = len(jax.devices())
    rows = []

    texts = {}
    for mb in (16, 64):
        size = mb * 1_000_000
        texts[mb] = corpus.make_corpus("genome", size, seed=0)
    pats = [texts[64][i * 1009 : i * 1009 + 8].copy() for i in range(8)]
    plans = eng.compile_patterns(list(pats))

    def scan(mb, recorder):
        sc = StreamScanner(plans, chunk, recorder=recorder)
        return sc.count_many(texts[mb])

    modes = {
        "none": lambda: None,
        "disabled": lambda: Recorder(enabled=False, fence=False),
        "traced": lambda: Recorder(enabled=True, fence=True),
    }
    for mb in (16, 64):
        size = mb * 1_000_000
        base = None
        for mode, make in modes.items():
            reps = 5 if mb == 64 and mode != "traced" else 3
            dt = timeit_median(
                lambda mb=mb, make=make: scan(mb, make()), reps=reps,
                label=f"obs/{mode}/{mb}mb",
            )
            if mode == "none":
                base = dt
            overhead = (dt / base - 1.0) * 100.0
            rows.append({
                "name": f"obs/{mode}/{mb}mb",
                "us_per_call": dt * 1e6,
                "GBps": size / dt / 1e9,
                "size_bytes": size,
                "chunk_bytes": chunk,
                "overhead_pct_vs_none": round(overhead, 2),
            })
            _emit(f"obs/{mode}/{mb}mb", dt * 1e6,
                  f"GBps={size/dt/1e9:.3f};overhead={overhead:+.2f}%")

    # -- host_prep vs dispatch split per shard count -------------------------
    size = 64_000_000
    text = texts[64]
    for S in (1, 2, 4, 8):
        # warm every device's compile cache outside the traced run
        warm = ShardedStreamScanner(plans, S, chunk)
        warm.count_many(text)
        rec = Recorder(enabled=True, fence=True)
        sc = ShardedStreamScanner(plans, S, chunk, recorder=rec)
        t0 = time.perf_counter()
        sc.count_many(text)
        dt = time.perf_counter() - t0
        split = rec.span_totals_ms()
        rows.append({
            "name": f"obs/shard_split/s{S}/64mb",
            "us_per_call": dt * 1e6,
            "GBps": size / dt / 1e9,
            "size_bytes": size,
            "chunk_bytes": chunk,
            "shards": S,
            "devices": ndev,
            "host_prep_ms": round(split.get("host_prep", 0.0), 1),
            "device_put_ms": round(split.get("device_put", 0.0), 1),
            "dispatch_ms": round(split.get("dispatch", 0.0), 1),
        })
        _emit(f"obs/shard_split/s{S}/64mb", dt * 1e6,
              f"host_prep={split.get('host_prep', 0.0):.0f}ms;"
              f"dispatch={split.get('dispatch', 0.0):.0f}ms")
        if S == 8:
            trace = rec.trace_json()
            validate_trace(trace)  # schema gate before the artifact lands
            (Path(outpath).parent / "obs_shard8_trace.json").write_text(
                json.dumps(trace, indent=1)
            )
    meta = {
        "host_cores": os.cpu_count(),
        "forced_devices": ndev,
        "none": "StreamScanner default: module-level disabled recorder "
                "(logging sink only) — the unconditional-call baseline",
        "disabled": "explicit Recorder(enabled=False): no spans, no "
                    "fencing; acceptance budget overhead_pct_vs_none < 2 "
                    "at 64 MB",
        "traced": "Recorder(enabled=True, fence=True): spans + per-dispatch "
                  "block_until_ready — attribution cost, deliberately paid",
        "compile_ms": drain_compile_ms(),
    }
    Path(outpath).write_text(json.dumps({"meta": meta, "rows": rows}, indent=1))


def bench_obs(outdir: Path):
    """Telemetry overhead + time-split bench (BENCH_obs.json): no-recorder
    vs disabled-recorder vs full-tracing throughput, and the per-shard
    host_prep/device_put/dispatch wall-time split, in a subprocess with
    XLA_FLAGS=--xla_force_host_platform_device_count=8 (same reasoning as
    bench_shard: device count locks at first jax init)."""
    import json
    import os
    import subprocess
    import sys

    out = outdir / "BENCH_obs.json"
    env = {
        **os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "JAX_PLATFORMS": "cpu",
        "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", ""),
    }
    res = subprocess.run(
        [
            sys.executable, "-c",
            "import sys; sys.path.insert(0, '.'); "
            "from benchmarks.run import _bench_obs_child; "
            "_bench_obs_child(sys.argv[1])",
            str(out),
        ],
        env=env,
        timeout=3600,
    )
    if res.returncode != 0:
        raise RuntimeError("bench_obs subprocess failed")
    for row in json.loads(out.read_text())["rows"]:
        _emit(row["name"], row["us_per_call"], f"GBps={row['GBps']:.3f}")


def bench_service(outdir: Path):
    """Grep-as-a-service QPS/latency bench (BENCH_service.json) — the first
    bench measuring REQUEST metrics, not GB/s: thousands of queries with
    Zipf-skewed pattern/corpus popularity from closed-loop concurrent
    clients, at several client counts, through three query-plane arms:

      * uncoalesced  — max_batch=1, no result cache: one engine dispatch
        per query, the per-query baseline every answer is bit-identical to;
      * coalesced    — the 2 ms micro-batching window (no result cache):
        concurrent queries against the same corpus share dispatches;
      * coalesced+cache — plus the keyed recent-result cache.

    Rows carry clients/qps/p50_ms/p99_ms and speedup_vs_uncoalesced (QPS
    ratio at the same client count).  GBps here is LOGICAL scanned
    throughput — queries x corpus bytes / wall — not device bandwidth; it
    exists so the shared row schema stays comparable, the meta says so.
    The canonical-plan warmup (DESIGN.md §15) runs before any timing, so
    compile cost lands in meta.compile_ms like every other bench."""
    import asyncio
    import json

    from repro.data import corpus as corpus_mod
    from repro.serve.query_plane import QueryPlane, ServiceConfig

    SIZE = 1 << 20          # per-corpus bytes (pow2: no index padding)
    N_CORPORA = 4
    POOL = 64               # distinct patterns, m=12 (selective: corpus-drawn
    #                         12-grams occur ~1-50x/MiB, like real grep
    #                         queries; every union size stays on the sparse
    #                         candidate path instead of the dense fallback)
    LEVELS = (8, 32, 64, 128, 256, 512)
    QUERIES = 1280          # per level per arm (same workload across arms)

    texts = {
        f"c{i}": corpus_mod.make_corpus("english", SIZE, seed=i).tobytes()
        for i in range(N_CORPORA)
    }
    pool = [
        bytes(p)
        for p in corpus_mod.extract_patterns(
            np.frombuffer(texts["c0"], np.uint8), 12, POOL, seed=7
        )
    ]
    rng = np.random.RandomState(3)
    pat_w = 1.0 / np.arange(1, POOL + 1) ** 1.1
    pat_w /= pat_w.sum()
    cor_w = 1.0 / np.arange(1, N_CORPORA + 1) ** 1.3
    cor_w /= cor_w.sum()

    def workload(level_seed: int):
        r = np.random.RandomState(level_seed)
        out = []
        for _ in range(QUERIES):
            cid = f"c{r.choice(N_CORPORA, p=cor_w)}"
            npat = 1 + int(r.randint(0, 3))
            pats = tuple(
                pool[i] for i in r.choice(POOL, size=npat, replace=False,
                                          p=pat_w)
            )
            out.append((cid, pats))
        return out

    ARMS = {
        "uncoalesced": ServiceConfig(coalesce_ms=0.0, max_batch=1,
                                     max_pending=4096,
                                     result_cache_entries=0),
        "coalesced": ServiceConfig(coalesce_ms=2.0, max_batch=64,
                                   max_pending=4096,
                                   result_cache_entries=0),
        "coalesced+cache": ServiceConfig(coalesce_ms=2.0, max_batch=64,
                                         max_pending=4096),
    }

    async def warmup():
        # compile every canonical shape signature the arms can hit: pow2
        # union sizes 1..POOL over the shared (1, SIZE) index shape
        plane = QueryPlane(ARMS["uncoalesced"])
        plane.add_corpus("c0", texts["c0"])
        for p in range(0, POOL.bit_length()):
            t0 = time.perf_counter()
            await plane.query("c0", pool[: 1 << p])
            _COMPILE_MS[f"service/union_P{1 << p}"] = (
                time.perf_counter() - t0
            ) * 1e3
        await plane.close()

    async def run_arm(cfg: ServiceConfig, clients: int, queries):
        plane = QueryPlane(cfg)
        for cid, text in texts.items():
            plane.add_corpus(cid, text)
        latencies: list = []

        async def worker(mine):
            for cid, pats in mine:
                t0 = time.perf_counter()
                await plane.query(cid, pats)
                latencies.append(time.perf_counter() - t0)

        t0 = time.perf_counter()
        await asyncio.gather(
            *[worker(queries[w::clients]) for w in range(clients)]
        )
        wall = time.perf_counter() - t0
        stats = plane.stats()
        await plane.close()
        lat = np.sort(np.asarray(latencies))
        q = len(lat)
        return {
            "wall_s": wall,
            "qps": q / wall,
            "mean_ms": float(lat.mean() * 1e3),
            "p50_ms": float(lat[q // 2] * 1e3),
            "p99_ms": float(lat[min(q - 1, int(q * 0.99))] * 1e3),
            "dispatches": stats["dispatches"],
            "cache_hits": stats["result_cache_hits"],
        }

    asyncio.run(warmup())
    rows = []
    base_qps: dict = {}
    for li, clients in enumerate(LEVELS):
        queries = workload(1000 + li)
        for arm, cfg in ARMS.items():
            r = asyncio.run(run_arm(cfg, clients, queries))
            if arm == "uncoalesced":
                base_qps[clients] = r["qps"]
            speed = r["qps"] / base_qps[clients]
            rows.append({
                "name": f"service/{arm}/clients{clients}",
                "us_per_call": r["mean_ms"] * 1e3,
                "GBps": r["qps"] * SIZE / 1e9,
                "size_bytes": SIZE,
                "clients": clients,
                "qps": round(r["qps"], 1),
                "p50_ms": round(r["p50_ms"], 3),
                "p99_ms": round(r["p99_ms"], 3),
                "speedup_vs_uncoalesced": round(speed, 2),
            })
            _emit(
                rows[-1]["name"], rows[-1]["us_per_call"],
                f"qps={rows[-1]['qps']};p50={rows[-1]['p50_ms']}ms;"
                f"p99={rows[-1]['p99_ms']}ms;x{rows[-1]['speedup_vs_uncoalesced']}",
            )
    meta = {
        "queries_per_level": QUERIES,
        "corpora": N_CORPORA,
        "corpus_bytes": SIZE,
        "pattern_pool": POOL,
        "pattern_m": 8,
        "popularity": "zipf(1.1) patterns, zipf(1.3) corpora",
        "closed_loop": True,
        "note": (
            "request-latency bench: GBps is LOGICAL throughput "
            "(qps x corpus_bytes / 1e9), not device bandwidth; "
            "speedup_vs_uncoalesced is the QPS ratio at equal clients"
        ),
        "compile_ms": drain_compile_ms(),
    }
    (outdir / "BENCH_service.json").write_text(
        json.dumps({"meta": meta, "rows": rows}, indent=1)
    )


def bench_pipeline(outdir: Path):
    from repro.data import corpus
    from repro.data.pipeline import LMDataPipeline

    docs = list(corpus.documents("english", 64, doc_len=8192, seed=0))
    t0 = time.perf_counter()
    pipe = LMDataPipeline(docs, seq_len=512, batch_size=8,
                          blocklist=[b"zzz", b"government "], dedup=True)
    n = sum(1 for _ in pipe)
    dt = time.perf_counter() - t0
    mb = 64 * 8192 / 1e6
    _emit("pipeline/filter+dedup", dt * 1e6, f"MBps={mb/dt:.1f};batches={n}")


def bench_roofline_report(outdir: Path):
    from benchmarks import roofline_report as rr

    recs = rr.load_records()
    if not recs:
        _emit("roofline/records", 0, "no dryrun records yet")
        return
    (outdir / "roofline.md").write_text(
        rr.summary(recs) + "\n\n" + rr.markdown_table(recs, "16x16")
    )
    _emit("roofline/records", len(recs), "see experiments/benchmarks/roofline.md")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=400_000)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 4MB texts, all 10 lengths")
    ap.add_argument(
        "benches", nargs="*",
        help="bench names to run (default: all); e.g. `bench_faults` or "
        "`faults stream` — the CI chaos job runs just bench_faults",
    )
    args = ap.parse_args()
    size = 4_000_000 if args.full else args.size
    outdir = Path("experiments/benchmarks")
    outdir.mkdir(parents=True, exist_ok=True)

    # fixed workload sizes below (1 MB multipattern/approx, the 16-256 MB
    # stream/megascan/shard/faults grids): the BENCH_*.json artifacts are
    # the perf trajectory future PRs diff, so their shape must not depend
    # on --size
    registry = {
        "paper_tables": lambda: bench_paper_tables(size, args.full, outdir),
        "kernels": lambda: bench_kernels(size, outdir),
        "multipattern": lambda: bench_multipattern(1_000_000, outdir),
        "dictionary": lambda: bench_dictionary(outdir),
        "approx": lambda: bench_approx(1_000_000, outdir),
        "stream": lambda: bench_stream(outdir),
        "megascan": lambda: bench_megascan(outdir),
        "shard": lambda: bench_shard(outdir),
        "faults": lambda: bench_faults(outdir),
        "obs": lambda: bench_obs(outdir),
        "service": lambda: bench_service(outdir),
        "pipeline": lambda: bench_pipeline(outdir),
        "roofline": lambda: bench_roofline_report(outdir),
    }
    picked = [b[len("bench_"):] if b.startswith("bench_") else b
              for b in args.benches]
    for b in picked:
        if b not in registry:
            ap.error(f"unknown bench {b!r}; choose from {sorted(registry)}")

    print("name,us_per_call,derived")
    for name in (picked or registry):
        registry[name]()
    # regenerate the markdown from the refreshed JSONs through the SAME
    # renderer CI's benchgate drift check runs
    from benchmarks import render_tables

    render_tables.write_markdown(outdir)


if __name__ == "__main__":
    main()
