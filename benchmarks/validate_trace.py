"""Schema validator for the Chrome/Perfetto traces repro.obs exports.

Stdlib-only (CI runs it straight after a fault-seeded scan, before the
trace is uploaded as an artifact).  Validates the contract DESIGN.md §13
promises, not just "is JSON":

  * top level is {"traceEvents": [...], "displayTimeUnit": "ms"};
  * every event has the common fields (name, ph, pid, tid) with the right
    types; "X" complete events carry numeric ts and dur >= 0; "i" instant
    events carry ts and scope "t"; "M" metadata events are thread_name
    declarations whose args name every tid used by real events;
  * per (pid, tid) lane, "X" spans are PROPERLY NESTED: sorted by
    (ts, -dur), each span either starts after the enclosing span ends or
    lies entirely inside it — overlap without containment is a recording
    bug (a span closed on the wrong lane).  ts/dur are rounded to 3
    decimals (0.001 us) on export, so containment is checked with a half-ulp
    epsilon;
  * structured args invariants: steal/shed/range_done/range_lost events
    carry int start < stop byte ranges; retry events carry an int attempt.

Usage:  python benchmarks/validate_trace.py TRACE.json [TRACE2.json ...]
prints "TRACE.json: OK (N events)" per file or raises TraceSchemaError.
"""

from __future__ import annotations

import json
import sys
from typing import List

# ts/dur are exported rounded to 3 decimal us; two rounded endpoints can
# each be off by half an ulp, so containment tolerates their sum.
EPS_US = 0.0011

RANGED_EVENTS = {"steal", "shed", "range_done", "range_lost"}


class TraceSchemaError(ValueError):
    """The trace violates the repro.obs export schema."""


def _fail(msg: str, i=None):
    where = "" if i is None else f" (event #{i})"
    raise TraceSchemaError(msg + where)


def _check_common(ev: dict, i: int):
    if not isinstance(ev, dict):
        _fail("event is not an object", i)
    for field, typ in (("name", str), ("ph", str), ("pid", int), ("tid", int)):
        if not isinstance(ev.get(field), typ):
            _fail(f"missing or mistyped {field!r}", i)
    args = ev.get("args")
    if args is not None and not isinstance(args, dict):
        _fail("args must be an object when present", i)


def _check_args(ev: dict, i: int):
    args = ev.get("args") or {}
    name = ev["name"]
    if name in RANGED_EVENTS:
        s, e = args.get("start"), args.get("stop")
        if not (isinstance(s, int) and isinstance(e, int) and s < e):
            _fail(f"{name!r} needs int args start < stop, got {args!r}", i)
    if name == "retry" and not isinstance(args.get("attempt"), int):
        _fail(f"'retry' needs int args.attempt, got {args!r}", i)


def _check_nesting(lane: tuple, spans: List[dict]):
    """spans: this lane's X events.  Sorted by (ts, -dur) a legal lane is a
    stack walk — each next span is either inside the top of the stack or
    after it; a partial overlap means a span leaked across lanes."""
    spans = sorted(spans, key=lambda e: (e["ts"], -e["dur"]))
    stack: List[tuple] = []  # (end_us, name)
    for ev in spans:
        t0, t1 = ev["ts"], ev["ts"] + ev["dur"]
        while stack and t0 >= stack[-1][0] - EPS_US:
            stack.pop()
        if stack and t1 > stack[-1][0] + EPS_US:
            _fail(
                f"lane {lane}: span {ev['name']!r} [{t0}, {t1}] overlaps "
                f"but is not nested in {stack[-1][1]!r} (ends {stack[-1][0]})"
            )
        stack.append((t1, ev["name"]))


def validate_trace(trace: dict) -> int:
    """Raise TraceSchemaError on violation; return the event count."""
    if not isinstance(trace, dict):
        _fail("trace must be a JSON object")
    if trace.get("displayTimeUnit") != "ms":
        _fail("displayTimeUnit must be 'ms'")
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        _fail("traceEvents must be a non-empty list")

    named_tids = set()
    used_tids = set()
    by_lane: dict = {}
    for i, ev in enumerate(events):
        _check_common(ev, i)
        ph = ev["ph"]
        if ph == "M":
            if ev["name"] != "thread_name":
                _fail(f"unexpected metadata event {ev['name']!r}", i)
            if not isinstance((ev.get("args") or {}).get("name"), str):
                _fail("thread_name metadata needs args.name", i)
            named_tids.add((ev["pid"], ev["tid"]))
            continue
        if ph not in ("X", "i"):
            _fail(f"unexpected phase {ph!r}", i)
        if not isinstance(ev.get("ts"), (int, float)) or ev["ts"] < 0:
            _fail("ts must be a non-negative number", i)
        used_tids.add((ev["pid"], ev["tid"]))
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                _fail("X event needs numeric dur >= 0", i)
            by_lane.setdefault((ev["pid"], ev["tid"]), []).append(ev)
        else:
            if ev.get("s") != "t":
                _fail("instant event needs scope 's': 't'", i)
            _check_args(ev, i)

    missing = used_tids - named_tids
    if missing:
        _fail(f"tids without thread_name metadata: {sorted(missing)}")
    for lane, spans in sorted(by_lane.items()):
        _check_nesting(lane, spans)
    return len(events)


def main(argv: List[str]) -> int:
    if not argv:
        print(__doc__.strip().splitlines()[-2].strip())
        return 2
    for path in argv:
        with open(path) as f:
            n = validate_trace(json.load(f))
        print(f"{path}: OK ({n} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
