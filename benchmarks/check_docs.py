"""Docs-link checker + quickstart extractor (stdlib only — CI's benchgate
and tier1 jobs both run it without jax installed).

    python benchmarks/check_docs.py [files...]      # default: README.md docs/*.md
    python benchmarks/check_docs.py --print-quickstart

Checks, for every markdown file given (default: README.md and docs/*.md,
plus DESIGN.md section-reference validation everywhere):

  * every relative markdown link target ``[text](path)`` exists (http(s)
    links are skipped; ``#anchor`` suffixes are stripped);
  * every ``DESIGN.md §N`` / ``§N–§M`` reference names a section that
    actually exists as a ``## §N `` heading in DESIGN.md;
  * every backticked repo path (`src/...py`, `benchmarks/...py`,
    `docs/...md`, ...) containing a ``/`` exists on disk (tokens with
    glob characters or spaces are skipped).

--print-quickstart prints the body of README.md's FIRST ```python fence so
CI can pipe it through an interpreter — the quickstart must actually run.
Exit status: 0 clean, 1 with one diagnostic line per failure.
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SECTION_REF = re.compile(r"DESIGN\.md\s*(§[0-9]+(?:[–-]§?[0-9]+)?)")
BACKTICK_PATH = re.compile(r"`([A-Za-z0-9_./-]+/[A-Za-z0-9_.-]+\.[A-Za-z0-9]+)`")
FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def design_sections() -> set:
    """Section numbers present as '## §N ' headings in DESIGN.md."""
    out = set()
    for line in (REPO / "DESIGN.md").read_text().splitlines():
        m = re.match(r"##\s+§(\d+)\b", line)
        if m:
            out.add(int(m.group(1)))
    return out


def expand_ref(ref: str) -> list:
    """'§7' -> [7]; '§1–§15' / '§1-15' -> [1..15]."""
    nums = [int(n) for n in re.findall(r"\d+", ref)]
    if len(nums) == 2:
        return list(range(nums[0], nums[1] + 1))
    return nums


def check_file(path: Path, sections: set) -> list:
    errors = []
    text = path.read_text()
    rel = path.relative_to(REPO)
    for m in MD_LINK.finditer(text):
        target = m.group(1).split("#", 1)[0]
        if not target or target.startswith(("http://", "https://", "mailto:")):
            continue
        resolved = (path.parent / target).resolve()
        if not resolved.exists():
            errors.append(f"{rel}: broken link target {m.group(1)!r}")
    for m in SECTION_REF.finditer(text):
        for n in expand_ref(m.group(1)):
            if n not in sections:
                errors.append(
                    f"{rel}: reference to DESIGN.md §{n}, which does not exist"
                )
    for m in BACKTICK_PATH.finditer(text):
        token = m.group(1)
        if any(c in token for c in "*{}<>"):
            continue
        if not ((REPO / token).exists() or (path.parent / token).exists()):
            errors.append(f"{rel}: backticked path `{token}` does not exist")
    return errors


def quickstart() -> str:
    m = FENCE.search((REPO / "README.md").read_text())
    if not m:
        raise SystemExit("README.md has no ```python fence")
    return m.group(1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*")
    ap.add_argument("--print-quickstart", action="store_true")
    args = ap.parse_args()
    if args.print_quickstart:
        print(quickstart())
        return 0
    files = [Path(f).resolve() for f in args.files] or (
        [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))
    )
    sections = design_sections()
    errors = []
    for f in files:
        if not f.exists():
            errors.append(f"{f}: file does not exist")
            continue
        errors.extend(check_file(f, sections))
    for e in errors:
        print(e, file=sys.stderr)
    if not errors:
        print(f"docs OK ({len(files)} files, {len(sections)} DESIGN sections)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
