"""int8 KV-cache decoding: quantize/dequantize round-trip and end-to-end
decode accuracy vs the full-precision cache."""

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import transformer as tf


def _cfg():
    return tf.LMConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=97, dtype="float32", param_dtype="float32",
        q_chunk=16, kv_chunk=16, ce_chunk=16,
    )


def test_quantize_roundtrip():
    x = jax.random.normal(jax.random.key(0), (3, 5, 2, 16))
    q, s = tf.quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (3, 5, 2)
    back = tf.dequantize_kv(q, s, jnp.float32)
    rel = np.abs(np.asarray(back - x)).max() / np.abs(np.asarray(x)).max()
    assert rel < 1.5 / 127  # one quantization step


def test_q8_decode_matches_fp():
    cfg = _cfg()
    params = tf.init_params(jax.random.key(0), cfg)
    B, S = 2, 32
    tok = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    logits_p, kc, vc = tf.prefill(params, cfg, tok)
    kc2, vc2 = tf.make_cache(cfg, B, S + 8, jnp.float32)
    kc2 = kc2.at[:, :, :S].set(kc)
    vc2 = vc2.at[:, :, :S].set(vc)
    nxt = jnp.argmax(logits_p, -1)[:, None]
    lg_fp, _, _ = tf.decode_step(params, cfg, nxt, jnp.int32(S), kc2, vc2)
    kq, vq = tf.quantize_cache(kc2, vc2)
    lg_q8, kq2, vq2 = tf.decode_step_q8(params, cfg, nxt, jnp.int32(S), kq, vq)
    rel = np.abs(np.asarray(lg_fp - lg_q8)).max() / np.abs(np.asarray(lg_fp)).max()
    assert rel < 0.05, rel
    np.testing.assert_array_equal(
        np.argmax(np.asarray(lg_fp), -1), np.argmax(np.asarray(lg_q8), -1)
    )
    # the cache was updated in place at `pos` (int8 entries present)
    assert kq2["q"].dtype == jnp.int8
    assert bool(jnp.any(kq2["q"][:, :, S] != 0))


def test_q8_multi_step_decode_stays_close():
    cfg = _cfg()
    params = tf.init_params(jax.random.key(2), cfg)
    B, S = 2, 16
    tok = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab)
    logits_p, kc, vc = tf.prefill(params, cfg, tok)
    max_len = S + 8
    kc2, vc2 = tf.make_cache(cfg, B, max_len, jnp.float32)
    kc2 = kc2.at[:, :, :S].set(kc)
    vc2 = vc2.at[:, :, :S].set(vc)
    kq, vq = tf.quantize_cache(kc2, vc2)
    nxt_fp = nxt_q8 = jnp.argmax(logits_p, -1)[:, None]
    agree = 0
    for step in range(6):
        lg_fp, kc2, vc2 = tf.decode_step(params, cfg, nxt_fp, jnp.int32(S + step), kc2, vc2)
        lg_q8, kq, vq = tf.decode_step_q8(params, cfg, nxt_q8, jnp.int32(S + step), kq, vq)
        a_fp = jnp.argmax(lg_fp, -1)
        a_q8 = jnp.argmax(lg_q8, -1)
        agree += int((a_fp == a_q8).sum())
        nxt_fp, nxt_q8 = a_fp[:, None], a_q8[:, None]
    assert agree >= 10  # 12 decisions total; tolerate tiny drift
