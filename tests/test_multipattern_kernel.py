"""Multi-pattern Pallas kernel (one VMEM pass, P patterns) vs the vmapped
single-pattern reference and the scalar oracle."""

import numpy as np
import pytest

from repro.core import baselines
from repro.kernels.multipattern import multipattern, multipattern_ref

from conftest import make_text


@pytest.mark.parametrize("sigma", [2, 4, 256])
@pytest.mark.parametrize("n", [100, 4095, 4097, 9000])
def test_multipattern_kernel_sweep(rng, sigma, n):
    t = make_text(rng, n, sigma)
    for n_pat in (1, 3, 8):
        for m in (4, 7, 8, 12):
            starts = rng.randint(0, n - m + 1, n_pat)
            ps = np.stack([t[s : s + m] for s in starts])
            got = np.asarray(multipattern(t, ps))
            np.testing.assert_array_equal(
                got, np.asarray(multipattern_ref(t, ps)), err_msg=f"P={n_pat} m={m}"
            )


def test_multipattern_matches_scalar_oracle(rng):
    t = make_text(rng, 3000, 4)
    ps = np.stack([t[10:18], t[100:108], np.full(8, 200, np.uint8)])
    got = np.asarray(multipattern(t, ps))
    for i in range(3):
        np.testing.assert_array_equal(got[i], baselines.naive_np(t, ps[i]))


def test_multipattern_small_tile_boundaries(rng):
    t = make_text(rng, 1024, 4)
    ps = np.stack([t[120:128], t[250:258]])  # straddle 128-byte tiles
    got = np.asarray(multipattern(t, ps, tile=128))
    for i in range(2):
        np.testing.assert_array_equal(got[i], baselines.naive_np(t, ps[i]))


def test_multipattern_errors(rng):
    t = make_text(rng, 100, 4)
    with pytest.raises(ValueError):
        multipattern(t, np.zeros((2, 3), np.uint8))  # m < 4
    with pytest.raises(ValueError):
        multipattern(t, np.zeros(8, np.uint8))  # not (P, m)
