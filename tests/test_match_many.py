"""Shared-text batched engine (core/engine.py): cross-checks against the
per-pattern single-text scan, ragged-padding semantics, and the serving
stop-scanner's one-dispatch-per-step contract."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import baselines, engine, epsm
from repro.core.multipattern import PatternSet, count_multi, find_multi

from conftest import make_text


def _mixed_patterns(rng, text, lengths):
    """Half extracted from the text (guaranteed hits), half random."""
    pats = []
    for m in lengths:
        s = rng.randint(0, len(text) - m + 1)
        pats.append(text[s : s + m].copy())
        pats.append(rng.randint(0, 5, size=m).astype(np.uint8))
    return pats


def test_match_many_mixed_lengths_vs_find(rng):
    """All three regimes in one plan set, cross-checked against epsm.find."""
    t = make_text(rng, 2000, 4)
    pats = _mixed_patterns(rng, t, (1, 2, 3, 5, 8, 12, 15, 16, 24, 40))
    plans = engine.compile_patterns(pats)
    order = engine.plan_order(plans)
    assert sorted(order.tolist()) == list(range(len(pats)))
    idx = engine.build_index(t)
    mask = np.asarray(engine.match_many_jit(idx, plans))
    counts = np.asarray(engine.count_many_jit(idx, plans))
    assert mask.shape == (1, len(pats), len(t))
    for row, pid in enumerate(order):
        want = np.asarray(epsm.find(t, pats[pid]))
        np.testing.assert_array_equal(mask[0, row], want, err_msg=f"pattern {pid}")
        assert counts[0, row] == want.sum()


def test_match_many_batched_ragged_padding(rng):
    """Batched texts with ragged true lengths: verdicts must match the
    per-document scan, and padding must never produce a match."""
    docs = [make_text(rng, n, 4) for n in (513, 100, 7, 256, 1)]
    pats = _mixed_patterns(rng, docs[0], (2, 6, 8, 20))
    plans = engine.compile_patterns(pats)
    order = engine.plan_order(plans)
    idx = engine.build_index(docs)  # pads to the longest doc
    assert idx.n == 513
    mask = np.asarray(engine.match_many_jit(idx, plans))
    for bi, doc in enumerate(docs):
        assert not mask[bi, :, len(doc) :].any(), "match inside padding"
        for row, pid in enumerate(order):
            np.testing.assert_array_equal(
                mask[bi, row, : len(doc)],
                baselines.naive_np(doc, pats[pid]),
                err_msg=f"doc {bi} pattern {pid}",
            )


def test_no_match_across_document_boundary(rng):
    """A pattern straddling two adjacent rows of the batch matrix must NOT
    match: each row is an independent document."""
    a = make_text(rng, 64, 4)
    b = make_text(rng, 64, 4)
    straddle = np.concatenate([a[-4:], b[:4]])  # exists only across the seam
    # make sure it doesn't accidentally occur inside either doc
    if baselines.naive_np(a, straddle).any() or baselines.naive_np(b, straddle).any():
        pytest.skip("straddle pattern occurs naturally (rng collision)")
    plans = engine.compile_patterns([straddle])
    idx = engine.build_index([a, b])
    assert not np.asarray(engine.match_many_jit(idx, plans)).any()
    # concatenated as ONE document it must match at the seam
    idx2 = engine.build_index(np.concatenate([a, b]))
    mask = np.asarray(engine.match_many_jit(idx2, plans))[0, 0]
    assert mask[60]


def test_engine_equals_vmap_multipattern(rng):
    """find_multi/count_multi (engine-backed) == the vmap baseline."""
    from repro.core.multipattern import count_multi_vmap, find_multi_vmap

    t = make_text(rng, 4096, 8)
    for m in (4, 8, 13):
        starts = rng.randint(0, len(t) - m + 1, 6)
        ps = np.stack([t[s : s + m] for s in starts])
        np.testing.assert_array_equal(
            np.asarray(find_multi(t, ps)), np.asarray(find_multi_vmap(t, ps))
        )
        np.testing.assert_array_equal(
            np.asarray(count_multi(t, ps)), np.asarray(count_multi_vmap(t, ps))
        )


def test_patternset_blocked_batch(rng):
    docs = [make_text(rng, 300, 4) for _ in range(8)]
    bad = b"\x01\x02\x03\x01\x02\x03\x00"
    planted = {2, 5}
    for i in planted:
        docs[i][100:107] = np.frombuffer(bad, np.uint8)
    ps = PatternSet([bad, b"\x09\x09"])
    idx = ps.index(docs)
    hits = np.asarray(jax.device_get(engine.any_hit(idx, ps.plans)))
    assert set(np.nonzero(hits)[0].tolist()) == planted
    counts = np.asarray(ps.count_each(docs[2]))
    assert counts.shape == (2,)


def test_count_many_shared_b_groups(rng, monkeypatch):
    """>= 2 eligible EPSMb groups count through the shared candidate pass
    (one union compaction for all groups — engine._count_groups_b_shared);
    results must match the per-pattern reference exactly, including a group
    with non-distinct fingerprints (duplicated pattern)."""
    monkeypatch.setattr(engine, "SPARSE_B_MIN_ELEMS", 0)
    t = make_text(rng, 4096, 4)
    pats = []
    for m in (5, 8, 12, 15):
        for _ in range(4):
            s = rng.randint(0, len(t) - m + 1)
            pats.append(t[s : s + m].copy())
    pats.append(pats[4].copy())  # duplicate: m=8 group loses `distinct`
    plans = engine.compile_patterns(pats)
    assert sum(
        1 for p in plans if p.regime == "b" and engine._sparse_b_eligible(
            engine.build_index(t), p
        )
    ) >= 2
    idx = engine.build_index(t)
    counts = np.asarray(engine.count_many(idx, plans))
    for row, pid in enumerate(engine.plan_order(plans)):
        want = int(np.asarray(epsm.find(t, pats[pid])).sum())
        assert counts[0, row] == want, f"pattern {pid}"


def test_count_many_single_eligible_b_group_uses_shared(rng, monkeypatch):
    """Regression (ISSUE 6 satellite): exactly ONE sparse-eligible EPSMb
    group in a mixed set must still route through _count_groups_b_shared —
    previously the `>= 2` routing threshold silently sent mixed sets down
    the slow per-group path.  Counts stay exact, and the dense lax.cond
    fallback inside the shared pass must cover the 1-group case too (checked
    here via the all-same-byte saturating text)."""
    monkeypatch.setattr(engine, "SPARSE_B_MIN_ELEMS", 0)
    calls = []
    orig = engine._count_groups_b_shared

    def spy(index, plans_, bank, end_min=None):
        calls.append(len(plans_))
        return orig(index, plans_, bank, end_min)

    monkeypatch.setattr(engine, "_count_groups_b_shared", spy)
    t = make_text(rng, 4096, 4)
    # a + b + c: the b group needs >= 4 patterns to be sparse-eligible, the
    # a/c groups never are — exactly one eligible group total
    pats = [t[7:9].copy(), t[90:114].copy()]
    for s in (50, 200, 600, 1100):
        pats.append(t[s : s + 8].copy())
    plans = engine.compile_patterns(pats)
    assert sum(
        1 for p in plans
        if p.regime == "b" and engine._sparse_b_eligible(engine.build_index(t), p)
    ) == 1
    idx = engine.build_index(t)
    counts = np.asarray(engine.count_many(idx, plans))
    assert calls == [1]
    for row, pid in enumerate(engine.plan_order(plans)):
        want = int(np.asarray(epsm.find(t, pats[pid])).sum())
        assert counts[0, row] == want, f"pattern {pid}"
    # saturating text: the single group's candidates overflow the budget and
    # the dense lax.cond branch inside the shared pass must stay exact
    calls.clear()
    tz = np.zeros(2048, np.uint8)
    pz = [np.zeros(8, np.uint8)] * 4
    plans_z = engine.compile_patterns(pz)
    idx_z = engine.build_index(tz)
    counts_z = np.asarray(engine.count_many(idx_z, plans_z))
    assert calls == [1]
    for row, pid in enumerate(engine.plan_order(plans_z)):
        want = baselines.naive_np(tz, pz[pid]).sum()
        assert counts_z[0, row] == want, f"pattern {pid}"


def test_count_many_shared_b_groups_overflow_dense(rng, monkeypatch):
    """Adversarial density through the SHARED path: all-same-byte text makes
    every block a union candidate, the budget overflows, and the dense
    fallback must keep every group's counts exact."""
    monkeypatch.setattr(engine, "SPARSE_B_MIN_ELEMS", 0)
    t = np.zeros(2048, np.uint8)
    pats = [np.zeros(8, np.uint8)] * 4 + [np.zeros(12, np.uint8)] * 4
    plans = engine.compile_patterns(pats)
    idx = engine.build_index(t)
    counts = np.asarray(engine.count_many(idx, plans))
    for row, pid in enumerate(engine.plan_order(plans)):
        want = baselines.naive_np(t, pats[pid]).sum()
        assert counts[0, row] == want, f"pattern {pid}"


def test_adversarial_density_falls_back_dense(rng):
    """All-same-byte text x matching pattern: every position is a candidate;
    the budget overflows and the dense branch must keep the result exact."""
    t = np.zeros(8192, np.uint8)
    pats = [np.zeros(8, np.uint8), np.zeros(24, np.uint8)]
    plans = engine.compile_patterns(pats)
    idx = engine.build_index(t)
    mask = np.asarray(engine.match_many_jit(idx, plans))
    counts = np.asarray(engine.count_many_jit(idx, plans))
    order = engine.plan_order(plans)
    for row, pid in enumerate(order):
        want = baselines.naive_np(t, pats[pid])
        np.testing.assert_array_equal(mask[0, row], want)
        assert counts[0, row] == want.sum()


def test_multipattern_kernel_long_patterns(rng):
    """m >= 16: the kernel must disable the window-fingerprint gate (the
    compiled plan's LUT is block-keyed there) and still verify exactly."""
    from repro.kernels.multipattern import multipattern

    t = make_text(rng, 3000, 4)
    for m in (16, 24, 36):
        ps = np.stack([t[50 : 50 + m], t[1000 : 1000 + m]])
        got = np.asarray(multipattern(t, ps))
        for i in range(2):
            np.testing.assert_array_equal(
                got[i], baselines.naive_np(t, ps[i]), err_msg=f"m={m} p={i}"
            )


def test_stop_scanner_one_dispatch_per_step():
    """Serving contract: exactly one jitted stop-scan dispatch per decode
    step, independent of batch size and stop-string count."""
    from repro.serve.engine import StopScanner

    streams = [b"hello stop here", b"xxxxxxxxxxxxxxx", b"stopstopstopsto"]
    stops = [b"stop", b"here", b"xx", b"\x00\x00\x00"]
    B, steps = len(streams), len(streams[0])
    scanner = StopScanner(stops, B, steps)
    first_hit = {}
    for step in range(steps):
        toks = np.asarray([s[step] for s in streams], np.int32)
        hits = scanner.scan(toks, step)
        assert hits.shape == (B, len(stops))
        for b in range(B):
            for si in np.nonzero(hits[b])[0]:
                first_hit.setdefault((b, si), step)
    assert scanner.dispatch_count == steps  # 1 per step, not B*stops per step
    # b"stop" ends at step 9 in stream 0; b"here" at 14; b"xx" at 1 in stream 1
    assert first_hit[(0, 0)] == 9
    assert first_hit[(0, 1)] == 14
    assert first_hit[(1, 2)] == 1
    assert first_hit[(2, 0)] == 3
    # the zero-byte stop must NOT fire from the uninitialized ring apron
    assert (2, 3) not in first_hit and (0, 3) not in first_hit


def _scan_stream(stops, stream, k=0):
    """Drive a 1-stream StopScanner; returns {stop_index: [hit steps]}."""
    from repro.serve.engine import StopScanner

    sc = StopScanner(stops, 1, len(stream), k=k)
    hits = {}
    for step in range(len(stream)):
        row = sc.scan(np.asarray([stream[step]], np.int32), step)[0]
        for si in np.nonzero(row)[0]:
            hits.setdefault(int(si), []).append(step)
    return hits, sc


def test_stop_scanner_ring_wraparound():
    """The tail ring is O(window) and slides at step % W == 0: stop
    occurrences spanning a wrap-around point (bytes written before AND after
    a slide) must still be reported, at every wrap over a long stream."""
    stop = b"abcd"  # W = 4: wraps at steps 4, 8, 12, ...
    # occurrences at starts 2 (spans the step-4 slide), 6 (spans step-8),
    # 11 (spans the step-12 slide at its last byte), and 16 (aligned)
    stream = b"xyabcdabcd_abcd_abcd"
    hits, sc = _scan_stream([stop], stream)
    assert sc.buf.shape == (1, 2 * len(stop) - 1)  # O(W), not O(max_new)
    want = [
        e for e in range(len(stream))
        if stream[e - 3 : e + 1] == stop and e >= 3
    ]
    assert hits.get(0, []) == want == [5, 9, 14, 19]
    assert sc.dispatch_count == len(stream)


def test_stop_scanner_two_stops_same_step():
    """Two stop sequences ending on the same decode step must BOTH be
    reported in that step's hit matrix (ties are not swallowed)."""
    stops = [b"abc", b"xbc", b"bc", b"zzzz"]
    stream = b"__abc__xbc"
    hits, _ = _scan_stream(stops, stream)
    # step 4 completes "abc" and "bc"; step 9 completes "xbc" and "bc"
    assert hits.get(0, []) == [4]
    assert hits.get(1, []) == [9]
    assert hits.get(2, []) == [4, 9]
    assert 3 not in hits


def test_stop_scanner_wraparound_exhaustive(rng):
    """Randomized cross-check: every (stop, stream) hit over a stream many
    times longer than the window agrees with the naive scan, so no boundary
    (apron edge, slide point, buffer end) drops or invents a match."""
    sigma = 3
    stops = [bytes(rng.randint(0, sigma, size=m).astype(np.uint8))
             for m in (2, 3, 5)]
    stream = bytes(rng.randint(0, sigma, size=64).astype(np.uint8))
    hits, _ = _scan_stream(stops, stream)
    for si, stop in enumerate(stops):
        want = [
            e for e in range(len(stream))
            if e >= len(stop) - 1
            and stream[e - len(stop) + 1 : e + 1] == stop
        ]
        assert hits.get(si, []) == want, f"stop {si}"
