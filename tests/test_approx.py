"""repro.approx — packed k-mismatch subsystem: engine integration, relaxed
fingerprint gate soundness, Pallas kernel agreement, and the fuzzy serving /
data-pipeline consumers (DESIGN.md §8)."""

import numpy as np
import pytest

from repro.approx import count_kmismatch, find_kmismatch, kmismatch_naive
from repro.core import engine, epsm

from conftest import make_text


def _mixed_patterns(rng, text, lengths):
    pats = []
    for m in lengths:
        s = rng.randint(0, len(text) - m + 1)
        pats.append(text[s : s + m].copy())
        pats.append(rng.randint(0, 5, size=m).astype(np.uint8))
    return pats


def test_k0_bit_identical_to_exact(rng):
    """match_many/count_many with k=0 must equal the exact path bit-for-bit,
    even on plans compiled with a nonzero mismatch budget."""
    docs = [make_text(rng, n, 4) for n in (513, 100, 7, 256)]
    pats = _mixed_patterns(rng, docs[0], (2, 3, 5, 8, 12, 16, 24))
    idx = engine.build_index(docs)
    exact = engine.compile_patterns(pats)
    fuzzy = engine.compile_patterns(pats, k=2)
    np.testing.assert_array_equal(
        np.asarray(engine.match_many_jit(idx, exact)),
        np.asarray(engine.match_many_jit(idx, fuzzy, k=0)),
    )
    np.testing.assert_array_equal(
        np.asarray(engine.count_many_jit(idx, exact)),
        np.asarray(engine.count_many_jit(idx, fuzzy, k=0)),
    )


def test_count_many_matches_naive_grid(rng):
    """Deterministic grid over regimes x alphabets x budgets, batched ragged
    texts, vs the naive k-mismatch reference."""
    for sigma in (2, 4, 256):
        docs = [make_text(rng, n, sigma) for n in (400, 37, 3)]
        for m in (2, 4, 5, 8, 12, 16):
            pats = [
                docs[0][: m].copy(),
                rng.randint(0, sigma, size=m).astype(np.uint8),
            ]
            for k in (1, 2, 3):
                plans = engine.compile_patterns(pats, k=k)
                order = engine.plan_order(plans)
                idx = engine.build_index(docs)
                mask = np.asarray(engine.match_many_jit(idx, plans, k=k))
                counts = np.asarray(engine.count_many_jit(idx, plans, k=k))
                for bi, doc in enumerate(docs):
                    assert not mask[bi, :, len(doc):].any(), "match in padding"
                    for row, pid in enumerate(order):
                        want = kmismatch_naive(doc, pats[pid], k)
                        np.testing.assert_array_equal(
                            mask[bi, row, : len(doc)], want,
                            err_msg=f"sigma={sigma} m={m} k={k} doc={bi}",
                        )
                        assert counts[bi, row] == want.sum()


def test_planted_fuzzy_occurrence_found(rng):
    """A corrupted copy of the pattern is invisible to the exact path and
    found by the k >= #typos budgets."""
    t = make_text(rng, 5000, 64)
    p = t[1000:1012].copy()
    site = 3000
    t[site : site + 12] = p
    t[site + 4] ^= 3
    t[site + 9] ^= 7  # two typos
    exact = set(np.nonzero(np.asarray(epsm.find(t, p)))[0].tolist())
    k1 = set(np.nonzero(np.asarray(find_kmismatch(t, p, 1)))[0].tolist())
    k2 = set(np.nonzero(np.asarray(find_kmismatch(t, p, 2)))[0].tolist())
    assert site not in exact and site not in k1 and site in k2
    assert exact <= k1 <= k2  # budgets are monotone


def test_relaxed_gate_sound_on_adversarial_density():
    """All-same-byte text: every position is a <= k candidate, the sparse
    budget overflows, and the dense fallback must keep counts exact."""
    t = np.zeros(8192, np.uint8)
    pats = [np.zeros(8, np.uint8), np.zeros(16, np.uint8)]
    for k in (1, 2):
        plans = engine.compile_patterns(pats, k=k)
        idx = engine.build_index(t)
        counts = np.asarray(engine.count_many_jit(idx, plans, k=k))
        order = engine.plan_order(plans)
        for row, pid in enumerate(order):
            assert counts[0, row] == kmismatch_naive(t, pats[pid], k).sum()


def test_relaxed_lut_covers_reachable_fingerprints(rng):
    """Gate soundness at the LUT level: the fingerprint of ANY window within
    Hamming distance k of the pattern must be registered."""
    from repro.approx.relaxed import relaxed_window_lut
    from repro.core.engine import (
        ENGINE_KBITS, _np_pack_words, _np_window_fingerprint, _word_offsets,
    )

    for m in (4, 7, 8, 13):
        p = rng.randint(0, 256, size=m).astype(np.uint8)
        lut = relaxed_window_lut(p[None, :], kbits=ENGINE_KBITS, k=1)
        assert lut is not None
        for _ in range(200):
            w = p.copy()
            j = rng.randint(0, m)
            w[j] = rng.randint(0, 256)  # <= 1 substitution
            fp = _np_window_fingerprint(
                _np_pack_words(w[None, :], _word_offsets(m)), ENGINE_KBITS
            )[0]
            assert lut[fp], f"m={m}: reachable fingerprint not registered"


def test_sparse_gated_count_path(rng):
    """Force the relaxed-LUT sparse path (P >= 4, B*n*P >= 8M, low union
    density) and cross-check against the naive reference on ragged rows."""
    from repro.approx.counting import BLOCK_FRAC_MAX, _block_frac

    # m=4 keeps the k=1 union LUT sparse enough for the block gate at P=4
    docs = [make_text(rng, n, 256) for n in (1_000_000, 50_000)]
    pats = [docs[0][s : s + 4].copy() for s in (1000, 50_000, 120_000, 333_333)]
    plans = engine.compile_patterns(pats, k=1)
    assert plans[0].relaxed_lut is not None
    assert _block_frac(plans[0]) <= BLOCK_FRAC_MAX, "gate should engage"
    assert len(docs) * 1_000_000 * len(pats) >= 8_000_000  # padded B*n*P
    idx = engine.build_index(docs)
    counts = np.asarray(engine.count_many_jit(idx, plans, k=1))
    order = engine.plan_order(plans)
    for bi, doc in enumerate(docs):
        for row, pid in enumerate(order):
            assert counts[bi, row] == kmismatch_naive(doc, pats[pid], 1).sum()


def test_kernel_matches_ref(rng):
    """Pallas kernel (interpret mode) vs the pure-jnp oracle across regimes,
    budgets, multi-tile grids, and ragged batched rows."""
    from repro.kernels.approx import approx_batched, approx_batched_ref

    for sigma in (4, 256):
        texts = np.stack([make_text(rng, 300, sigma) for _ in range(2)])
        lengths = np.asarray([300, 117], np.int32)
        for m in (2, 5, 8, 16):
            ps = np.stack([
                texts[0][40 : 40 + m],
                rng.randint(0, sigma, size=m).astype(np.uint8),
            ])
            for k in (0, 1, 2):
                got = np.asarray(
                    approx_batched(texts, ps, k, lengths, tile=128)
                )
                want = np.asarray(approx_batched_ref(texts, ps, k, lengths))
                np.testing.assert_array_equal(
                    got, want, err_msg=f"sigma={sigma} m={m} k={k}"
                )


def test_epsm_find_k_kwarg(rng):
    """epsm.find/count/positions expose the budget as a kwarg."""
    t = make_text(rng, 800, 4)
    p = rng.randint(0, 4, size=6).astype(np.uint8)
    want = kmismatch_naive(t, p, 1)
    np.testing.assert_array_equal(np.asarray(epsm.find(t, p, k=1)), want)
    assert int(epsm.count(t, p, k=1)) == want.sum()
    np.testing.assert_array_equal(
        epsm.positions(t, p, k=1), np.nonzero(want)[0]
    )
    assert int(count_kmismatch(t, p, 1)) == want.sum()


def test_fuzzy_stop_scanner():
    """Serving tolerance mode: a typo'd stop sequence still stops the
    stream at the right step when k=1; the exact scanner never fires."""
    from repro.serve.engine import StopScanner

    stream = b"aa STOPW0RD bbbbbbbb"  # O -> 0 typo in the generated bytes
    for k, expect in ((0, []), (1, [10])):
        sc = StopScanner([b"STOPWORD"], 1, len(stream), k=k)
        fired = []
        for step in range(len(stream)):
            hits = sc.scan(np.asarray([stream[step]], np.int32), step)
            if hits[0, 0]:
                fired.append(step)
        assert fired == expect, (k, fired)
        assert sc.dispatch_count == len(stream)


def test_pipeline_fuzzy_blocklist(rng):
    """Data-plane consumer: blocklist_k=1 drops documents containing a
    one-typo corruption of a blocked term; k=0 keeps them."""
    from repro.data.pipeline import LMDataPipeline

    bad = b"forbiddenterm"
    docs = [
        rng.randint(97, 123, size=2000).astype(np.uint8) for _ in range(6)
    ]
    corrupted = np.frombuffer(bad, np.uint8).copy()
    corrupted[5] ^= 2
    for i in (1, 4):
        docs[i][300 : 300 + len(bad)] = corrupted
    blocked = {}
    for k in (0, 1):
        pipe = LMDataPipeline(
            iter([d.copy() for d in docs]), seq_len=64, batch_size=2,
            blocklist=[bad], blocklist_k=k,
        )
        for _ in pipe:
            pass
        blocked[k] = pipe.stats.docs_blocked
    assert blocked == {0: 0, 1: 2}, blocked
