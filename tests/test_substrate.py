"""Substrate tests: data pipeline (EPSM filter/dedup), corpus, optimizer,
checkpointing (atomic/resume/elastic), watchdog, gradient compression."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import corpus
from repro.data.pipeline import BOS, LMDataPipeline
from repro.dist.compat import make_mesh
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def test_corpus_generators():
    for name in ("genome", "protein", "english"):
        t = corpus.make_corpus(name, 10_000, seed=1)
        assert t.dtype == np.uint8 and len(t) == 10_000
        t2 = corpus.make_corpus(name, 10_000, seed=1)
        np.testing.assert_array_equal(t, t2)  # deterministic
    g = corpus.make_corpus("genome", 1000)
    assert set(np.unique(g)) <= set(b"ACGT")


def test_pipeline_blocklist_filter():
    bad = b"GATTACA"
    docs = []
    for i in range(40):
        d = corpus.make_corpus("genome", 512, seed=i)
        if i % 4 == 0:  # plant the blocked pattern
            d = d.copy()
            d[100:107] = np.frombuffer(bad, np.uint8)
        docs.append(d)
    pipe = LMDataPipeline(docs, seq_len=128, batch_size=2, blocklist=[bad])
    batches = list(pipe)
    assert pipe.stats.docs_blocked == 10
    assert pipe.stats.docs_out == 30
    for b in batches:
        assert b["tokens"].shape == (2, 128)
        assert b["tokens"].max() <= BOS
        # the blocked pattern never reaches training data
        flat = b["tokens"].astype(np.uint8).reshape(-1)
        from repro.core import epsm

        assert int(epsm.count(flat, np.frombuffer(bad, np.uint8))) == 0


def test_pipeline_dedup():
    base = corpus.make_corpus("english", 1024, seed=7)
    docs = [base, base.copy(), corpus.make_corpus("english", 1024, seed=8)]
    pipe = LMDataPipeline(docs, seq_len=64, batch_size=1, dedup=True)
    list(pipe)
    assert pipe.stats.docs_deduped == 1


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(peak_lr=0.2, warmup_steps=5, total_steps=200, weight_decay=0.0)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 1.0))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-2


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones(5, jnp.bfloat16)}}
    ckpt.save(tree, tmp_path, step=10)
    ckpt.save(tree, tmp_path, step=20)
    restored, step = ckpt.restore(tree, tmp_path)
    assert step == 20
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16
    # keep-K GC
    for s in (30, 40, 50):
        ckpt.save(tree, tmp_path, step=s, keep=2)
    assert ckpt.latest_step(tmp_path) == 50
    import pathlib

    assert len(list(pathlib.Path(tmp_path).glob("step_*"))) == 2


def test_checkpoint_async_and_atomic(tmp_path):
    tree = {"w": jnp.ones((64, 64))}
    t = ckpt.save(tree, tmp_path, step=1, async_=True)
    t.join()
    restored, step = ckpt.restore(tree, tmp_path)
    assert step == 1
    # no stray tmp dirs after publish
    import pathlib

    assert not list(pathlib.Path(tmp_path).glob(".tmp_*"))


def test_elastic_restore_resharding(tmp_path):
    """Checkpoint saved unsharded restores onto a sharded layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(tree, tmp_path, step=5)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = ckpt.restore(tree, tmp_path, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_watchdog_detects_straggler():
    import time

    from repro.dist.fault_tolerance import StepWatchdog, StragglerAbort

    wd = StepWatchdog(factor=5.0, policy="raise")
    for s in range(6):
        wd.start_step(s)
        time.sleep(0.003)
        wd.end_step()
    wd.start_step(6)
    time.sleep(0.1)
    with pytest.raises(StragglerAbort):
        wd.end_step()
    assert wd.events and wd.events[0].step == 6


def test_run_with_retries_classifies_errors():
    """Transient I/O retries up to the budget; programming errors and
    FatalScanError re-raise on the FIRST attempt — a TypeError from plan
    construction must not burn retries behind backoff."""
    from repro.dist.fault_tolerance import (
        FatalScanError,
        run_with_retries,
    )

    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise IOError("transient")
        return "ok"

    seen = []
    assert (
        run_with_retries(flaky, retries=5, on_failure=lambda a, e: seen.append(a))
        == "ok"
    )
    assert calls["n"] == 3 and seen == [0, 1]

    for exc_type in (TypeError, ValueError, KeyError, FatalScanError):
        calls = {"n": 0}

        def fatal():
            calls["n"] += 1
            raise exc_type("bad plan")

        with pytest.raises(exc_type):
            run_with_retries(fatal, retries=5)
        assert calls["n"] == 1  # no retry budget burned

    # a custom classifier overrides the default
    calls = {"n": 0}

    def vflaky():
        calls["n"] += 1
        if calls["n"] < 2:
            raise ValueError("transiently malformed")
        return "ok"

    assert (
        run_with_retries(
            vflaky, retries=3, is_retryable=lambda e: isinstance(e, ValueError)
        )
        == "ok"
    )
    assert calls["n"] == 2


def test_run_with_retries_backoff_schedule():
    """Delays follow the jittered exponential policy exactly (seeded), cap
    at max_s, and the final failing attempt sleeps nothing."""
    from repro.dist.fault_tolerance import BackoffPolicy, run_with_retries

    delays = []

    def always():
        raise IOError("down")

    with pytest.raises(IOError):
        run_with_retries(
            always,
            retries=4,
            backoff=BackoffPolicy(base_s=0.1, factor=2.0, max_s=0.3, jitter=0.5, seed=3),
            sleep=delays.append,
        )
    assert len(delays) == 4  # one per retried attempt, none after the last
    ref = BackoffPolicy(base_s=0.1, factor=2.0, max_s=0.3, jitter=0.5, seed=3)
    assert delays == pytest.approx([ref.delay_s(a) for a in range(4)])
    # jitterless policy is the pure exponential with a cap
    flat = BackoffPolicy(base_s=0.1, factor=2.0, max_s=0.3, jitter=0.0)
    assert [flat.delay_s(a) for a in range(4)] == pytest.approx(
        [0.1, 0.2, 0.3, 0.3]
    )
    with pytest.raises(ValueError):
        BackoffPolicy(jitter=2.0)


def test_gradient_compression_accuracy():
    """int8+EF quantized psum ~= exact psum, and EF kills the bias over steps."""
    from jax.sharding import PartitionSpec as P

    from repro.dist.compat import make_mesh, shard_map
    from repro.dist.compression import quantized_psum, zeros_residuals

    mesh = make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.RandomState(0).randn(128, 8), jnp.float32)}
    res = zeros_residuals(g)

    def f(g, r):
        return quantized_psum(g, r, "data")

    out, new_res = shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False
    )(g, res)
    rel = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max() / np.abs(
        np.asarray(g["w"])
    ).max()
    assert rel < 1e-2  # single quantization step error bound
    # error feedback: residual + dequantized == original exactly
    recon = np.asarray(out["w"]) + np.asarray(new_res["w"])
    np.testing.assert_allclose(recon, np.asarray(g["w"]), rtol=0, atol=1e-6)
