"""Telemetry-plane acceptance (DESIGN.md §13): the flight recorder tells the
TRUTH about a chaotic scan, and costs nothing when off.

  * a fault-seeded stealing sharded scan exports a schema-valid
    Chrome/Perfetto trace: spans properly nested per lane, ONE retry event
    per injected recoverable fault, every steal/shed carrying its exact
    beta-aligned byte range, and range_done events that exactly tile the
    input — verified against the same clean oracles test_fault_injection
    uses (extend the sweep with FAULT_SEEDS=0,1,2,... like the chaos job);
  * partial-mode coverage: merged range_done ranges == the PartialScanResult
    covered complement, event-for-struct;
  * the disabled recorder is inert (shared NULL_SPAN, no buffers) while
    events still reach log sinks;
  * straggler flags and the auto-chunk probe route through the recorder;
  * benchmarks/validate_trace.py accepts real exports and rejects each
    schema violation class it claims to catch.
"""

import json
import sys
import time
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks.validate_trace import TraceSchemaError, validate_trace  # noqa: E402
from test_fault_injection import FAULT_SEEDS, _corpus  # noqa: E402

from repro.core.shard_stream import PartialScanResult, ShardedStreamScanner
from repro.core.stream import StreamScanner
from repro.dist.fault_injection import FaultPlan, FaultyRangeSource
from repro.dist.fault_tolerance import (
    BackoffPolicy,
    FatalScanError,
    StepWatchdog,
    run_with_retries,
)
from repro.dist.sharding import merge_ranges
from repro.obs import NULL, NULL_SPAN, Metrics, Recorder


def _tiles(ranges, total):
    """True iff the (start, stop) ranges exactly tile [0, total)."""
    ranges = sorted((int(s), int(e)) for s, e in ranges)
    if not ranges or ranges[0][0] != 0 or ranges[-1][1] != total:
        return False
    return all(a[1] == b[0] for a, b in zip(ranges, ranges[1:]))


# -- the acceptance property: traced chaos scan ----------------------------


@pytest.mark.parametrize("seed", FAULT_SEEDS)
def test_traced_faulty_stealing_scan(rng, seed, tmp_path):
    """Fault-seeded stealing scan with the recorder on: results stay
    bit-identical, the trace is schema-valid (per-lane nesting included),
    retries map 1:1 to injected aborting faults, steal/shed ranges are
    beta-aligned, and range_done events exactly tile the input."""
    text, plans = _corpus(rng)
    want = StreamScanner(plans, 4096).count_many(text)

    plan = FaultPlan(
        seed, read_error_rate=0.08, crash_rate=0.12, attempts_per_fault=1,
    )
    rec = Recorder(enabled=True, fence=False)
    sc = ShardedStreamScanner(
        plans, 4, 4096, max_retries=16, fault_plan=plan,
        steal=True, steal_workers=3, min_steal_bytes=1024,
        backoff=BackoffPolicy(base_s=0.0, jitter=0.0),
        recorder=rec,
    )
    np.testing.assert_array_equal(
        sc.count_many(FaultyRangeSource(text, plan, piece_bytes=8192)), want
    )

    # one retry per injected fault: every fault here aborts its attempt
    # (read errors + crashes, no latency), and the budget never exhausts
    faults = rec.events_named("fault")
    retries = rec.events_named("retry")
    assert len(faults) == len(retries)
    assert sum(plan.counts_by_action().values()) == len(faults)
    assert all(isinstance(e["attempt"], int) for e in retries)

    # every steal/shed carries its exact beta-aligned byte range
    moves = rec.events_named("steal") + rec.events_named("shed")
    assert len(moves) == len(sc.steal_events)
    for ev in moves:
        assert 0 <= ev["start"] < ev["stop"] <= len(text)
        assert ev["start"] % 8 == 0
    for ev in rec.events_named("steal"):
        assert ev["thief"] is not None

    # retired ranges exactly tile the input despite repartitioning
    done = [(e["start"], e["stop"]) for e in rec.events_named("range_done")]
    assert _tiles(done, len(text))

    # the export passes the same validator CI runs (incl. span nesting)
    trace = rec.trace_json()
    assert validate_trace(trace) == len(trace["traceEvents"])
    names = {ev["name"] for ev in trace["traceEvents"] if ev["ph"] == "X"}
    assert {"host_prep", "device_put", "dispatch", "scan_range"} <= names
    out = tmp_path / "trace.json"
    rec.export_trace(out)
    assert validate_trace(json.loads(out.read_text())) > 0


def test_partial_scan_range_done_matches_covered(rng):
    """Permanent crashes + on_exhausted='partial': the union of range_done
    events IS the covered complement the PartialScanResult reports."""
    text, plans = _corpus(rng, n=64_000)
    plan = FaultPlan(1, crash_rate=0.5, attempts_per_fault=None)
    rec = Recorder(enabled=True, fence=False)
    sc = ShardedStreamScanner(
        plans, 8, 2048, max_retries=1, fault_plan=plan,
        on_exhausted="partial", steal=True, steal_workers=3,
        min_steal_bytes=512, recorder=rec,
    )
    res = sc.count_many(text)
    assert isinstance(res, PartialScanResult)
    assert not res.complete
    done = [(e["start"], e["stop"]) for e in rec.events_named("range_done")]
    assert merge_ranges(done) == res.covered
    lost = [(e["start"], e["stop"]) for e in rec.events_named("range_lost")]
    assert lost, "exhausted ranges must be recorded as range_lost events"
    assert _tiles(done + list(res.missing), len(text))
    validate_trace(rec.trace_json())


# -- disabled path ----------------------------------------------------------


def test_disabled_recorder_is_inert_but_sinks_still_fire(rng):
    captured = []
    rec = Recorder(
        enabled=False, fence=False,
        sinks=(lambda name, args: captured.append((name, dict(args))),),
    )
    assert rec.span("anything", lane="x", a=1) is NULL_SPAN
    obj = object()
    assert NULL_SPAN.fence(obj) is obj  # no sync, identity passthrough
    NULL_SPAN.set(a=1)  # no-op, no error

    text, plans = _corpus(rng, n=20_000)
    want = StreamScanner(plans, 2048).count_many(text)
    got = StreamScanner(plans, 2048, recorder=rec).count_many(text)
    np.testing.assert_array_equal(got, want)
    assert rec.trace_json()["traceEvents"] == []  # nothing buffered
    assert rec.events_named("fault") == []

    rec.event("straggler", step=3, duration_s=0.5)
    assert captured == [("straggler", {"step": 3, "duration_s": 0.5})]
    assert rec.events_named("straggler") == []  # sink-only when disabled


# -- satellite routing: stragglers + auto-chunk probe ----------------------


def test_straggler_flag_routes_through_recorder(rng):
    text, plans = _corpus(rng, n=40_000)
    rec = Recorder(enabled=True, fence=False)
    flagged = []

    def slow_source():
        for i in range(0, len(text), 4096):
            if i == 6 * 4096:
                time.sleep(0.05)  # one stalled read, well past 3x median
            yield text[i : i + 4096]

    sc = StreamScanner(
        plans, 4096, recorder=rec,
        watchdog=StepWatchdog(factor=3.0, policy="log", min_history=3),
        on_straggler=flagged.append,
    )
    want = StreamScanner(plans, 4096).count_many(text)
    np.testing.assert_array_equal(sc.count_many(slow_source()), want)

    evs = rec.events_named("straggler")
    assert evs and len(evs) == len(flagged)  # recorder and callback agree
    for ev, cb in zip(evs, flagged):
        assert ev["step"] == cb.step
        assert ev["duration_s"] > 0 and ev["factor"] >= 3.0


def test_auto_chunk_probe_routes_through_recorder():
    from repro.core import engine

    rec = Recorder(enabled=True, fence=False)
    sc = StreamScanner(
        engine.compile_patterns([b"abab"]), "auto", recorder=rec
    )
    (ev,) = rec.events_named("auto_chunk")
    assert ev["chunk_bytes"] == sc.chunk_bytes > 0
    assert ev["dispatch_overhead_us"] > 0


# -- retry-loop + remote-reader events -------------------------------------


def test_run_with_retries_emits_structured_events():
    rec = Recorder(enabled=True, fence=False)
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise IOError("transient")
        return "ok"

    assert run_with_retries(
        flaky, retries=5, recorder=rec, label="shard3",
        backoff=BackoffPolicy(base_s=0.0, jitter=0.0),
    ) == "ok"
    evs = rec.events_named("retry")
    assert [e["attempt"] for e in evs] == [0, 1]
    assert all(e["task"] == "shard3" for e in evs)

    with pytest.raises(IOError):
        run_with_retries(
            lambda: (_ for _ in ()).throw(IOError("always")),
            retries=1, recorder=rec, label="doomed",
            backoff=BackoffPolicy(base_s=0.0, jitter=0.0),
        )
    (ex,) = rec.events_named("retry_exhausted")
    assert ex["task"] == "doomed" and ex["attempt"] == 1

    with pytest.raises(FatalScanError):
        run_with_retries(
            lambda: (_ for _ in ()).throw(FatalScanError("auth")),
            retries=5, recorder=rec, label="fatal",
        )
    (ft,) = rec.events_named("retry_fatal")
    assert ft["task"] == "fatal" and ft["attempt"] == 0
    assert len(rec.events_named("retry")) == 3  # exhausted run retried once


def test_remote_reader_records_part_spans_and_retries():
    from repro.core.remote_source import FakeObjectStore

    data = bytes(range(256)) * 64  # 16 KiB
    plan = FaultPlan(5, read_error_rate=0.4, attempts_per_fault=1)
    rec = Recorder(enabled=True, fence=False)
    store = FakeObjectStore(data, plan=plan)
    reader = store.reader(
        part_bytes=1024, prefetch=2, retries=4,
        backoff=BackoffPolicy(base_s=0.0, jitter=0.0), sleep=lambda s: None,
        recorder=rec,
    )
    out = b"".join(bytes(a) for a in reader(0, len(data)))
    assert out == data
    assert reader.stats.retries > 0, "seed 5 @ 40% must fault at least once"
    assert len(rec.events_named("part_retry")) == reader.stats.retries
    spans = rec.summary()["spans"]
    assert spans["part_wait"]["count"] == reader.stats.parts == 16
    m = rec.metrics.summary()["counters"]
    assert m["remote_parts"] == 16 and m["remote_bytes"] == len(data)
    validate_trace(rec.trace_json())


def test_stop_scanner_records_fenced_spans():
    from repro.serve.engine import StopScanner

    rec = Recorder(enabled=True, fence=True)
    sc = StopScanner([b"ab"], batch=2, max_new=8, recorder=rec)
    hits = []
    for step, byte in enumerate(b"xaab"):
        hits.append(sc.scan(np.array([byte, ord("x")]), step))
    assert hits[3][0, 0] and not hits[3][1, 0]
    assert rec.summary()["spans"]["stop_scan"]["count"] == sc.dispatch_count == 4
    assert rec.metrics.summary()["counters"]["stop_scan_dispatches"] == 4


# -- metrics + export plumbing ---------------------------------------------


def test_metrics_summary_and_report():
    m = Metrics()
    m.count("dispatches")
    m.count("bytes", 100)
    m.count("bytes", 50)
    m.gauge("chunk", 4096)
    for v in range(1, 101):
        m.observe("lat", float(v))
    s = m.summary()
    assert s["counters"] == {"bytes": 150, "dispatches": 1}
    assert s["gauges"] == {"chunk": 4096}
    h = s["histograms"]["lat"]
    assert h["count"] == 100 and h["min"] == 1 and h["max"] == 100
    assert h["p50"] == 51 and h["p99"] == 100 and h["mean"] == 50.5
    assert m.report() == m.report()  # deterministic
    assert "counter" in m.report() and "hist" in m.report()


def test_chrome_export_structure_and_nesting():
    rec = Recorder(enabled=True, fence=False)
    with rec.span("outer", lane="laneA", k=1):
        with rec.span("inner", lane="laneA"):
            pass
    with rec.span("other", lane="laneB"):
        pass
    rec.event("steal", victim=0, thief=2, start=0, stop=8)
    trace = rec.trace_json()
    assert trace["displayTimeUnit"] == "ms"
    validate_trace(trace)
    evs = trace["traceEvents"]
    meta = {e["args"]["name"]: e["tid"] for e in evs if e["ph"] == "M"}
    # the instant event fell on the thread-name fallback lane (MainThread)
    assert {"laneA", "laneB"} < set(meta)
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert xs["inner"]["tid"] == xs["outer"]["tid"] == meta["laneA"]
    assert xs["outer"]["ts"] <= xs["inner"]["ts"]
    assert (xs["inner"]["ts"] + xs["inner"]["dur"]
            <= xs["outer"]["ts"] + xs["outer"]["dur"] + 0.0011)
    (inst,) = [e for e in evs if e["ph"] == "i"]
    assert inst["name"] == "steal" and inst["s"] == "t"
    assert rec.report().startswith("== scan telemetry ==")


def test_validator_rejects_each_violation_class():
    def lane_meta(tid=1):
        return {"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                "args": {"name": f"lane{tid}"}}

    def x(name, ts, dur, tid=1):
        return {"name": name, "ph": "X", "pid": 0, "tid": tid,
                "ts": ts, "dur": dur}

    ok = {"displayTimeUnit": "ms",
          "traceEvents": [lane_meta(), x("a", 0.0, 10.0), x("b", 1.0, 2.0)]}
    assert validate_trace(ok) == 3

    bad = [
        ["not an object"],
        {"traceEvents": [lane_meta()]},                      # no time unit
        {"displayTimeUnit": "ms", "traceEvents": []},        # empty
        {"displayTimeUnit": "ms",                            # unnamed tid
         "traceEvents": [x("a", 0.0, 1.0, tid=9)]},
        {"displayTimeUnit": "ms",                            # X without dur
         "traceEvents": [lane_meta(),
                         {"name": "a", "ph": "X", "pid": 0, "tid": 1,
                          "ts": 0.0}]},
        {"displayTimeUnit": "ms",                            # overlap, no nest
         "traceEvents": [lane_meta(), x("a", 0.0, 5.0), x("b", 3.0, 5.0)]},
        {"displayTimeUnit": "ms",                            # steal sans range
         "traceEvents": [lane_meta(),
                         {"name": "steal", "ph": "i", "pid": 0, "tid": 1,
                          "ts": 0.0, "s": "t", "args": {"victim": 0}}]},
        {"displayTimeUnit": "ms",                            # retry w/o attempt
         "traceEvents": [lane_meta(),
                         {"name": "retry", "ph": "i", "pid": 0, "tid": 1,
                          "ts": 0.0, "s": "t", "args": {"task": "x"}}]},
    ]
    for trace in bad:
        with pytest.raises(TraceSchemaError):
            validate_trace(trace)


def test_compile_ms_accounting():
    """timeit_median's warmup call (jit compile) lands in the BENCH meta as
    compile_ms instead of polluting the GB/s medians (satellite: warmup
    accounting fix)."""
    from benchmarks.run import drain_compile_ms, timeit_median

    drain_compile_ms()  # isolate from any earlier labels
    calls = []

    def fn():
        calls.append(1)
        if len(calls) == 1:
            time.sleep(0.02)  # the "compile"

    dt = timeit_median(fn, reps=3, label="obs/test")
    assert dt < 0.02, "warmup time must not leak into the median"
    ms = drain_compile_ms()
    assert set(ms) == {"obs/test"} and ms["obs/test"] >= 15.0
    assert drain_compile_ms() == {}  # drained


def test_default_recorder_is_shared_disabled_null():
    assert NULL.enabled is False
    from repro.core import shard_stream as shard_mod
    from repro.core import stream as stream_mod

    for mod in (stream_mod, shard_mod):
        assert mod._DEFAULT_REC.enabled is False
        assert mod._DEFAULT_REC.sinks  # log lines survive as a sink
