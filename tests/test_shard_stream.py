"""Sharded streaming scans (core/shard_stream.py): bit-identity against the
single-host StreamScanner across shard counts, shard-seam phase coverage,
degenerate (narrow/empty) shards, range sources, fault retry, and the
repro.dist collective merge.

This file is the CI `multihost` job's main cargo: it runs both on the plain
single-CPU tier-1 device and under XLA_FLAGS=--xla_force_host_platform_
device_count=8, where the per-shard device placement and the cross-device
count reduction are genuinely multi-device (tests that need >= 2 devices
self-skip on the single-device run)."""

import io
import pathlib

import numpy as np
import pytest

import jax

from repro.core import engine
from repro.core.shard_stream import (
    ShardedStreamScanner,
    ShortRangeRead,
    open_range,
    read_range,
    shard_stream_count,
    source_total_bytes,
)
from repro.core.stream import Compressed, StreamScanner
from repro.dist.compat import make_mesh, sum_across_devices
from repro.dist.fault_tolerance import InjectedFault
from repro.dist.sharding import make_stream_shard_spec, range_partition

from conftest import make_text

LENGTHS = (2, 4, 8, 13, 16, 32)
SHARDS = (1, 2, 3, 4, 8)
CHUNK = 997  # odd: window seams land mid-beta-block after rounding


def _patterns(rng, text):
    """One extracted (guaranteed-hit) pattern per length, plus one random."""
    pats = []
    for m in LENGTHS:
        s = rng.randint(0, len(text) - m + 1)
        pats.append(text[s : s + m].copy())
        pats.append(rng.randint(0, 5, size=m).astype(np.uint8))
    return pats


def test_sharded_bit_identical_to_single_host(rng):
    """The acceptance property: sharded count/positions are bit-identical to
    the single-host StreamScanner for shard counts {1,2,3,4,8} across the
    m x k sweep (all LENGTHS in one plan set per k)."""
    for k in (0, 1):
        n = int(rng.randint(3000, 6000))
        text = make_text(rng, n, 4)
        plans = engine.compile_patterns(_patterns(rng, text), k=k)
        want_counts = StreamScanner(plans, CHUNK, k=k).count_many(text)
        want_pos = StreamScanner(plans, CHUNK, k=k).positions_many(text)
        for S in SHARDS:
            sc = ShardedStreamScanner(plans, S, CHUNK, k=k)
            np.testing.assert_array_equal(
                sc.count_many(text), want_counts, err_msg=f"k={k} S={S}"
            )
            pos = ShardedStreamScanner(plans, S, CHUNK, k=k).positions_many(text)
            for r in range(len(pos)):
                np.testing.assert_array_equal(
                    pos[r], want_pos[r], err_msg=f"k={k} S={S} row {r}"
                )


def test_sharded_megakernel_path_bit_identical(rng):
    """The shard bit-identity sweep through the MEGAKERNEL path: every shard
    scanner consumes fused Pallas kernel outputs (use_kernel=True,
    interpret-mode on CPU) and must match the per-group two-pass reference
    (fused=False) exactly across shard counts."""
    n = int(rng.randint(3000, 6000))
    text = make_text(rng, n, 4)
    plans = engine.compile_patterns(_patterns(rng, text))
    want = ShardedStreamScanner(plans, 2, CHUNK, fused=False).count_many(text)
    for S in (1, 3):
        sc = ShardedStreamScanner(plans, S, CHUNK, use_kernel=True)
        assert sc._scanner(0).spec is not None  # kernel path really engaged
        np.testing.assert_array_equal(
            sc.count_many(text), want, err_msg=f"S={S}"
        )


def test_planted_matches_straddle_every_shard_seam_phase():
    """Occurrences planted across every shard boundary at EVERY straddle
    phase (first byte left of the seam ... last byte right of it) are found
    exactly once, counts and positions."""
    for S in (2, 4, 8):
        for m in LENGTHS:
            pat = np.full(m, 9, np.uint8)  # alphabet disjoint from the text
            plans = engine.compile_patterns([pat])
            sc = ShardedStreamScanner(plans, S, 256)
            text = make_text(np.random.RandomState(100 * S + m), 4096 + 13, 4)
            spec = sc.shard_spec(len(text))
            starts = []
            for s_i, _ in spec.ranges[1:]:  # every interior boundary
                starts += [s_i - m + 1 + j for j in range(m + 1)]
            starts = sorted(
                {s for s in starts if 0 <= s <= len(text) - m}
            )
            # plant with >= 1 byte gaps: abutting all-9 plants would merge
            # into runs with extra (unplanned) occurrences
            planted, last_end = [], -1
            for s in starts:
                if s > last_end:
                    text[s : s + m] = pat
                    planted.append(s)
                    last_end = s + m
            got = ShardedStreamScanner(plans, S, 256).count_many(text)
            assert got.tolist() == [len(planted)], f"S={S} m={m}"
            pos = ShardedStreamScanner(plans, S, 256).positions_many(text)
            np.testing.assert_array_equal(
                pos[0], np.asarray(planted), err_msg=f"S={S} m={m}"
            )


def test_shard_narrower_than_overlap_and_empty_shards():
    """Shards narrower than max_m - 1 (an occurrence can span several whole
    shards) and fully empty shards (more shards than beta blocks) stay
    exact."""
    m = 32
    rng = np.random.RandomState(7)
    text = make_text(rng, 64, 4)
    text[5 : 5 + m] = 9  # spans shards of width 8 entirely
    plans = engine.compile_patterns([np.full(m, 9, np.uint8)])
    want = StreamScanner(plans, 256).count_many(text)
    assert want.tolist() == [1]
    for S in (2, 8, 16, 64):
        got = ShardedStreamScanner(plans, S, 256).count_many(text)
        assert got.tolist() == want.tolist(), f"S={S}"
        pos = ShardedStreamScanner(plans, S, 256).positions_many(text)
        np.testing.assert_array_equal(pos[0], [5], err_msg=f"S={S}")
    # degenerate: stream shorter than one beta block, more shards than bytes
    short = text[:5].copy()
    got = ShardedStreamScanner(plans, 8, 256).count_many(short)
    assert got.tolist() == [0]


def test_range_partition_properties():
    for total, S, align in ((1000, 4, 8), (7, 3, 8), (0, 2, 8), (8192, 8, 8)):
        ranges = range_partition(total, S, align=align)
        assert len(ranges) == S
        assert ranges[0][0] == 0 and ranges[-1][1] == total
        for (a, b), (c, _) in zip(ranges, ranges[1:]):
            assert a <= b == c  # contiguous, monotone; empty shards legal
            assert b % align == 0 or b == total  # interior bounds aligned
    spec = make_stream_shard_spec(1000, 4, overlap=32, align=8)
    assert spec.prefix_range(0) == (0, 0)
    s1 = spec.ranges[1][0]
    assert spec.prefix_range(1) == (s1 - 32, s1)
    with pytest.raises(ValueError):
        make_stream_shard_spec(1000, 4, overlap=33, align=8)  # misaligned ov


def test_sources_path_file_callable_agree(rng, tmp_path):
    text = make_text(rng, 20_000, 4)
    pats = [text[70:78].copy(), text[10:26].copy()]
    plans = engine.compile_patterns(pats)
    want = StreamScanner(plans, 2048).count_many(text)
    p = pathlib.Path(tmp_path) / "corpus.bin"
    p.write_bytes(text.tobytes())
    got_path = ShardedStreamScanner(plans, 4, 2048).count_many(p)
    with open(p, "rb") as f:
        got_file = ShardedStreamScanner(plans, 4, 2048).count_many(f)
    opens = []

    def ranged(start, stop):
        opens.append((start, stop))
        return text[start:stop]

    got_call = ShardedStreamScanner(plans, 4, 2048).count_many(
        ranged, total_bytes=len(text)
    )
    assert (
        want.tolist() == got_path.tolist() == got_file.tolist() == got_call.tolist()
    )
    assert len(opens) == 7  # 4 shard bodies + 3 overlap prefixes
    assert source_total_bytes(p) == len(text)
    # compressed sources have no random access: partitioning must refuse
    with pytest.raises(TypeError):
        source_total_bytes(Compressed(b"xx"))


def test_shard_stream_count_original_order(rng):
    text = make_text(rng, 10_000, 4)
    pats = [text[70:102].copy(), text[10:12].copy(), text[500:508].copy()]
    got = shard_stream_count(text, pats, n_shards=4, chunk_bytes=1024)
    want = shard_stream_count(text, pats, n_shards=1, chunk_bytes=1024)
    assert got.tolist() == want.tolist()


def test_fault_injection_retry_and_exhaustion(rng):
    text = make_text(rng, 16_000, 4)
    plans = engine.compile_patterns([text[70:78].copy()])
    want = StreamScanner(plans, 2048).count_many(text)
    fails = {"n": 0}

    def flaky(start, stop):
        if start >= 8000 and start < 12000 and fails["n"] == 0:
            fails["n"] += 1
            raise InjectedFault("shard node died")
        return text[start:stop]

    sc = ShardedStreamScanner(plans, 4, 2048, max_retries=1)
    got = sc.count_many(flaky, total_bytes=len(text))
    assert got.tolist() == want.tolist()  # retried shard re-counts exactly
    assert [e.shard for e in sc.events] == [2] and sc.events[0].attempt == 0

    def dead(start, stop):
        raise InjectedFault("gone for good")

    sc2 = ShardedStreamScanner(plans, 4, 2048, max_retries=2)
    with pytest.raises(InjectedFault):
        sc2.count_many(dead, total_bytes=len(text))
    assert len(sc2.events) == 3  # every attempt logged, then re-raised


def test_fatal_errors_bypass_the_shard_retry_budget(rng):
    """Non-retryable errors (programming errors, FatalScanError) surface on
    the FIRST attempt — no pointless re-open-and-rescan of a shard that
    fails deterministically.  A custom is_retryable hook overrides."""
    from repro.dist.fault_tolerance import FatalScanError

    text = make_text(rng, 16_000, 4)
    plans = engine.compile_patterns([text[70:78].copy()])
    want = StreamScanner(plans, 2048).count_many(text)

    for exc in (FatalScanError("object gone"), TypeError("bad plan")):
        calls = {"n": 0}

        def fatal(start, stop, _exc=exc):
            calls["n"] += 1
            raise _exc

        sc = ShardedStreamScanner(plans, 2, 2048, max_retries=5)
        with pytest.raises(type(exc)):
            sc.count_many(fatal, total_bytes=len(text))
        assert calls["n"] == 1  # first attempt only
        assert len(sc.events) == 1  # still logged for the postmortem

    heal = {"n": 0}

    def flaky_value(start, stop):
        heal["n"] += 1
        if heal["n"] == 1:
            raise ValueError("transiently malformed")
        return text[start:stop]

    sc = ShardedStreamScanner(
        plans, 2, 2048, max_retries=2,
        is_retryable=lambda e: isinstance(e, ValueError),
    )
    got = sc.count_many(flaky_value, total_bytes=len(text))
    assert got.tolist() == want.tolist()


def test_short_range_read_is_loud_not_an_undercount(rng):
    """A source that delivers fewer bytes than a shard's range (truncated
    file, misbehaving range callable) must raise — transiently short reads
    retry, persistent ones propagate; silent undercounts are impossible."""
    text = make_text(rng, 16_000, 4)
    plans = engine.compile_patterns([text[70:78].copy()])
    want = StreamScanner(plans, 2048).count_many(text)
    flaky = {"n": 0}

    def short_once(start, stop):
        if start >= 8000 and start < 12000 and flaky["n"] == 0:
            flaky["n"] += 1
            return text[start : stop - 100]  # transient truncation
        return text[start:stop]

    sc = ShardedStreamScanner(plans, 4, 2048, max_retries=1)
    got = sc.count_many(short_once, total_bytes=len(text))
    assert got.tolist() == want.tolist()
    assert len(sc.events) == 1 and "ShortRangeRead" in sc.events[0].error

    def always_short(start, stop):
        return text[start : max(start, stop - 7)]

    with pytest.raises(ShortRangeRead):
        ShardedStreamScanner(plans, 4, 2048, max_retries=1).count_many(
            always_short, total_bytes=len(text)
        )
    # a stale total_bytes (file truncated after stat) is equally loud
    with pytest.raises(ShortRangeRead):
        ShardedStreamScanner(plans, 2, 2048).count_many(
            lambda s, e: text[s : min(e, 9000)], total_bytes=len(text)
        )


def test_open_range_views_do_not_copy(rng):
    text = make_text(rng, 1024, 4)
    view = open_range(text, 64, 512)
    assert isinstance(view, np.ndarray) and view.base is not None
    np.testing.assert_array_equal(read_range(text, 8, 16), text[8:16])


# ---------------------------------------------------------------------------
# multi-device paths (real under the CI multihost job's 8 forced devices)
# ---------------------------------------------------------------------------

def test_multi_device_placement_and_collective_merge(rng):
    if len(jax.local_devices()) < 2:
        pytest.skip("needs >= 2 local devices (CI multihost job)")
    text = make_text(rng, 100_000, 4)
    pats = [text[11:19].copy(), text[500:532].copy()]
    plans = engine.compile_patterns(pats)
    want = StreamScanner(plans, 8192).count_many(text)
    sc = ShardedStreamScanner(plans, None, 8192)  # defaults to device count
    assert sc.n_shards == jax.device_count()
    got = sc.count_many(text)
    np.testing.assert_array_equal(got, want)
    # plan state was replicated to every device the shards landed on
    assert len(sc._replicas) == min(sc.n_shards, len(jax.local_devices()))
    pos = ShardedStreamScanner(plans, None, 8192).positions_many(text)
    want_pos = StreamScanner(plans, 8192).positions_many(text)
    for r in range(len(pos)):
        np.testing.assert_array_equal(pos[r], want_pos[r])


def test_sum_across_devices_collective(rng):
    devs = jax.local_devices()
    parts = [
        jax.device_put(np.full(3, i + 1, np.int32), devs[i % len(devs)])
        for i in range(5)
    ]
    np.testing.assert_array_equal(sum_across_devices(parts), np.full(3, 15))


def test_distributed_scan_inprocess_mesh(rng):
    """The repro.dist collective scan on an in-process 8-device mesh — the
    multihost job's every-PR replacement for the weekly subprocess test."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices (CI multihost job)")
    from repro.core import baselines, distributed

    mesh = make_mesh((8,), ("data",))
    t = make_text(rng, 8 * 512, 4)
    for m in (2, 9, 17):
        p = t[40 : 40 + m].copy()
        oracle = baselines.naive_np(t, p)
        f = distributed.make_distributed_find(mesh, "data")
        np.testing.assert_array_equal(
            np.asarray(f(jax.numpy.asarray(t), jax.numpy.asarray(p))), oracle
        )
        c = distributed.make_distributed_count(mesh, "data")
        assert int(c(jax.numpy.asarray(t), jax.numpy.asarray(p))) == oracle.sum()


def test_compat_make_mesh_fallback_branch():
    """The manual-Mesh branch (pre-0.4.35 jax, or an explicit device subset)
    builds the same mesh shape as jax.make_mesh."""
    devs = jax.devices()
    mesh = make_mesh((1,), ("data",), devices=devs[:1])
    assert mesh.axis_names == ("data",) and mesh.shape["data"] == 1
    with pytest.raises(ValueError):
        make_mesh((len(devs) + 1,), ("data",), devices=devs)
