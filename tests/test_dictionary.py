"""Dictionary-scale matching (DESIGN.md §14): partitioned union-LUTs,
CSR payloads, and the packed Aho-Corasick fallback.

The contract under test is BIT-IDENTITY: a bucketed plan set must produce
exactly the flat plan set's counts/masks at every P, on every route
(sparse CSR, slot-dense, automaton, streaming seams, sharded seams) —
only the cost model may differ.  The adversarial tests additionally pin
that a fingerprint flood reroutes (measured density trigger) without
changing a single count.

The chaos CI job re-runs the FAULT_SEEDS-parametrized tests below with
extra seeds (FAULT_SEEDS=0,1,2,... like tests/test_fault_injection.py).
"""

import os

import numpy as np
import pytest

import jax

from repro.core import baselines, engine
from repro.core.automaton import (
    compile_automaton,
    automaton_states,
    count_automaton,
)
from repro.core.multipattern import PatternSet
from repro.core.stream import StreamScanner
from repro.core.shard_stream import ShardedStreamScanner
from repro.kernels.acscan.ref import ac_states_ref, count_ref
from repro.kernels.megascan import build_mega_spec
from repro.obs.recorder import Recorder

from conftest import make_text

FAULT_SEEDS = [int(s) for s in os.environ.get("FAULT_SEEDS", "0,1").split(",")]


def _dict_patterns(rng, P, m, sigma=256):
    """P distinct random patterns of length m."""
    pats = rng.randint(0, sigma, size=(P * 2, m)).astype(np.uint8)
    pats = np.unique(pats, axis=0)
    assert pats.shape[0] >= P
    return [p for p in pats[:P]]


def _planted_text(rng, pats, n, sigma=256, every=7):
    """Random text with every ``every``-th pattern planted at a fixed spot."""
    t = make_text(rng, n, sigma)
    for i in range(0, len(pats), every):
        m = len(pats[i])
        pos = (i * 131) % (n - m)
        t[pos : pos + m] = pats[i]
    return t


def _flood_text(pats, n):
    """Adversarial texture: the dictionary tiled end to end — every window
    at a pattern boundary probes a REGISTERED fingerprint, so candidate
    density saturates while the match set stays exactly countable."""
    m = len(pats[0])
    reps = [np.asarray(pats[i % len(pats)]) for i in range(n // m + 1)]
    return np.concatenate(reps)[:n]


def _counts(idx, plans, **kw):
    return np.asarray(engine.count_many(idx, plans, **kw))


# ---------------------------------------------------------------------------
# bucketed == flat bit-identity across P x m x k
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", list(range(2, 17)))
@pytest.mark.parametrize("k", [0, 1])
def test_bucketed_equals_flat_small_p(rng, m, k):
    """P=32 (below DICT_BUCKET_MIN_P): bucket=True must still be
    bit-identical to the flat plans, for every regime and k."""
    pats = _dict_patterns(rng, 32, m, sigma=8)
    text = _planted_text(rng, pats, 2048, sigma=8, every=3)
    idx = engine.build_index(text)
    flat = engine.compile_patterns(pats, k=k, bucket=False, automaton=False)
    buck = engine.compile_patterns(pats, k=k, bucket=True, automaton=False)
    np.testing.assert_array_equal(
        _counts(idx, flat), _counts(idx, buck), err_msg=f"m={m} k={k}"
    )
    np.testing.assert_array_equal(
        np.asarray(engine.match_many(idx, flat)),
        np.asarray(engine.match_many(idx, buck)),
        err_msg=f"m={m} k={k} (match)",
    )


@pytest.mark.parametrize("m", [2, 5, 8, 15])
@pytest.mark.parametrize("k", [0, 1])
def test_bucketed_equals_flat_p1000(rng, m, k):
    """P=1000 (auto-bucketed): counts equal the flat plans and, for the
    extracted patterns, the naive oracle."""
    pats = _dict_patterns(rng, 1000, m)
    text = _planted_text(rng, pats, 4096)
    idx = engine.build_index(text)
    flat = engine.compile_patterns(pats, k=k, bucket=False, automaton=False)
    buck = engine.compile_patterns(pats, k=k)
    if m >= 4:  # EPSMa groups never bucket
        assert any(
            p.slot_off is not None or p.c_slot_off is not None for p in buck
        )
    cf, cb = _counts(idx, flat), _counts(idx, buck)
    np.testing.assert_array_equal(cf, cb, err_msg=f"m={m} k={k}")
    if k == 0:
        order = engine.plan_order(buck)
        for row in range(0, 1000, 97):
            pid = order[row]
            assert cb[0, row] == baselines.naive_np(text, pats[pid]).sum()


def test_bucketed_equals_flat_p10000(rng):
    """P=10k mixed-length dictionary, one dispatch, vs the flat plans."""
    pats = _dict_patterns(rng, 5000, 8) + _dict_patterns(rng, 5000, 16)
    text = _planted_text(rng, pats, 1 << 14, every=11)
    idx = engine.build_index(text)
    flat = engine.compile_patterns(pats, bucket=False, automaton=False)
    buck = engine.compile_patterns(pats)
    bb = [p for p in buck if p.slot_off is not None]
    assert bb and bb[0].bbits > 0, "P=5k groups must widen the fingerprint"
    assert bb[0].automaton is not None, "dictionary scale builds the automaton"
    np.testing.assert_array_equal(_counts(idx, flat), _counts(idx, buck))


def test_bucketed_duplicate_patterns(rng):
    """Duplicate patterns each get their own CSR slot entry and count."""
    base = _dict_patterns(rng, 64, 8)
    pats = base + base[:16]
    text = _planted_text(rng, pats, 2048, every=2)
    idx = engine.build_index(text)
    flat = engine.compile_patterns(pats, bucket=False, automaton=False)
    buck = engine.compile_patterns(pats, bucket=True, automaton=True)
    cf, cb = _counts(idx, flat), _counts(idx, buck)
    np.testing.assert_array_equal(cf, cb)
    # the duplicated rows really count the same occurrences
    order = engine.plan_order(buck).tolist()
    for i in range(16):
        assert cb[0, order.index(64 + i)] == cb[0, order.index(i)]


def test_canonical_epsmc_slot_overflow_bit_identity():
    """An EPSMc CSR slot can hold MORE than P entries: patterns sharing a
    repeated (or common) >= beta byte block register the same fingerprint
    at every inspected offset, so occ.max() can reach P * stride.  The
    canonical pow2 quantization must clamp slot_max against the plan's
    TOTAL entry count, never against P — regression for a min(P, ...)
    clamp that rounded slot_max DOWN and made _c_verify_csr skip live
    entries (silently dropped matches on the serving path)."""
    pats = [b"a" * 16, b"a" * 15 + b"b"]  # every aligned block is "aaaaaaaa"
    raw = b"x" + b"a" * 60 + b"y" + b"a" * 15 + b"b" + b"a" * 20
    text = np.frombuffer(raw, np.uint8).copy()
    idx = engine.build_index(text)
    flat = engine.compile_patterns(pats, bucket=False, automaton=False)
    canon = engine.compile_patterns(
        pats, bucket=True, automaton=False, canonical=True
    )
    (plan,) = canon
    assert plan.c_slot_off is not None, "must exercise the CSR route"
    assert plan.slot_max > len(pats), "overflow scenario: slot deeper than P"
    np.testing.assert_array_equal(_counts(idx, flat), _counts(idx, canon))
    np.testing.assert_array_equal(
        np.asarray(engine.match_many(idx, flat)),
        np.asarray(engine.match_many(idx, canon)),
    )
    # sanity vs the naive oracle, not just flat-vs-bucketed
    order = engine.plan_order(canon)
    cc = _counts(idx, canon)
    for row in range(len(pats)):
        pid = order[row]
        assert cc[0, row] == baselines.naive_np(text, pats[pid]).sum()


# ---------------------------------------------------------------------------
# streaming / sharded seams
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [512, 997])
def test_bucketed_streaming_seams(rng, chunk):
    """StreamScanner over bucketed plans == flat plans == whole-text scan,
    with occurrences straddling chunk seams."""
    pats = _dict_patterns(rng, 300, 8, sigma=4)
    text = _planted_text(rng, pats, 6000, sigma=4, every=2)
    flat = engine.compile_patterns(pats, bucket=False, automaton=False)
    buck = engine.compile_patterns(pats, bucket=True, automaton=True)
    want = StreamScanner(flat, chunk).count_many(text)
    got = StreamScanner(buck, chunk).count_many(text)
    np.testing.assert_array_equal(want, got)
    idx = engine.build_index(text)
    np.testing.assert_array_equal(got[None, :], _counts(idx, buck))


@pytest.mark.parametrize("shards", [2, 3])
def test_bucketed_sharded_seams(rng, shards):
    """Sharded scan over bucketed plans: shard seams + chunk seams."""
    pats = _dict_patterns(rng, 200, 8, sigma=4)
    text = _planted_text(rng, pats, 8000, sigma=4, every=2)
    flat = engine.compile_patterns(pats, bucket=False, automaton=False)
    buck = engine.compile_patterns(pats, bucket=True, automaton=True)
    want = ShardedStreamScanner(flat, shards, 997).count_many(bytes(text))
    got = ShardedStreamScanner(buck, shards, 997).count_many(bytes(text))
    np.testing.assert_array_equal(want, got)


def test_cached_compile_dictionary_key_and_no_transfer(rng):
    """The plan cache keys on (k, bucket, automaton): variants don't
    collide, and a hit re-serves the SAME plan tuple with zero host->device
    transfers (jax.transfer_guard enforced)."""
    pats = [bytes(p) for p in _dict_patterns(rng, 150, 8)]
    a = engine.compile_patterns_cached(pats)
    b = engine.compile_patterns_cached(pats, bucket=False)
    assert a is not b
    assert any(p.slot_off is not None for p in a)
    assert all(p.slot_off is None for p in b)
    with jax.transfer_guard("disallow"):
        again = engine.compile_patterns_cached(pats)
    assert again is a


# ---------------------------------------------------------------------------
# adversarial routing (measured-density trigger)
# ---------------------------------------------------------------------------


def test_adversarial_flood_routes_and_counts(rng, monkeypatch):
    """A fingerprint flood overflows the measured union budget and reroutes
    to the automaton — with bit-identical counts; average text on the same
    plans stays on the sparse CSR gather.  route_probe shares the
    dispatcher's decision and emits the fallback_route event."""
    monkeypatch.setattr(engine, "SPARSE_B_MIN_ELEMS", 0)
    P, m, n = 1500, 8, 1 << 19  # n large enough for the budget to bind
    pats = _dict_patterns(rng, P, m)
    flat = engine.compile_patterns(pats, bucket=False, automaton=False)
    buck = engine.compile_patterns(pats, bucket=True, automaton=True)
    assert any(p.automaton is not None for p in buck)

    avg = _planted_text(rng, pats, n)
    flood = _flood_text(pats, n)
    events = []
    rec = Recorder(sinks=((lambda name, args: events.append((name, args))),))

    idx_a = engine.build_index(avg)
    info_a = engine.route_probe(idx_a, buck, recorder=rec)
    assert info_a["route"] == "sparse"
    assert info_a["blocks"] <= info_a["budget"]

    idx_f = engine.build_index(flood)
    info_f = engine.route_probe(idx_f, buck, recorder=rec)
    assert info_f["route"] == "automaton"
    assert info_f["blocks"] > info_f["budget"]
    assert info_f["density"] > 2 * info_a["density"]

    names = [nm for nm, _ in events]
    assert names.count("fallback_route") == 2

    for idx in (idx_a, idx_f):
        np.testing.assert_array_equal(_counts(idx, flat), _counts(idx, buck))


@pytest.mark.parametrize("seed", FAULT_SEEDS)
def test_adversarial_determinism_seed_sweep(seed, monkeypatch):
    """Chaos-sweep hook: at every seed, the adversarial texture's bucketed
    counts are deterministic across repeat dispatches and equal the flat
    plans'.  (The CI chaos job widens FAULT_SEEDS.)"""
    monkeypatch.setattr(engine, "SPARSE_B_MIN_ELEMS", 0)
    r = np.random.RandomState(0xD1C7 + seed)
    pats = _dict_patterns(r, 400, 8, sigma=16)
    text = _flood_text(pats, 1 << 15)
    idx = engine.build_index(text)
    flat = engine.compile_patterns(pats, bucket=False, automaton=False)
    buck = engine.compile_patterns(pats, bucket=True, automaton=True)
    c1, c2 = _counts(idx, buck), _counts(idx, buck)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_array_equal(c1, _counts(idx, flat))


# ---------------------------------------------------------------------------
# packed automaton vs sequential reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", FAULT_SEEDS)
def test_automaton_counts_vs_naive(seed):
    """Mixed lengths, duplicates, nested suffixes; lax.scan and kernel
    paths; end_min seam gate — all against the naive oracle."""
    r = np.random.RandomState(0xAC0 + seed)
    pats = [r.randint(0, 4, size=m).astype(np.uint8) for m in (2, 3, 5, 8, 8)]
    pats.append(pats[0].copy())           # duplicate
    pats.append(pats[3][2:7].copy())      # embedded substring
    auto = compile_automaton(pats)
    assert auto is not None
    text = r.randint(0, 4, size=(2, 777)).astype(np.uint8)
    lengths = np.array([777, 640])
    for kernel in (False, True):
        got = np.asarray(
            count_automaton(text, lengths, auto, use_kernel=kernel)
        )
        for b in range(2):
            want = count_ref(text[b], lengths[b], pats)
            np.testing.assert_array_equal(
                got[b], want, err_msg=f"kernel={kernel} row={b}"
            )
    # end_min keeps only occurrences ending at or past the bound
    g = np.asarray(count_automaton(text, lengths, auto, end_min=100))
    for b in range(2):
        want = np.zeros(len(pats), np.int64)
        for i, p in enumerate(pats):
            mask = baselines.naive_np(text[b][: lengths[b]], p)
            pos = np.nonzero(mask)[0]
            want[i] = int((pos + len(p) - 1 >= 100).sum())
        np.testing.assert_array_equal(g[b], want)


def test_automaton_states_match_sequential(rng):
    """Segmented-parallel states == one-byte-at-a-time reference, on both
    the lax.scan and Pallas kernel paths, at a seam-unfriendly seg."""
    pats = [make_text(rng, m, 3) for m in (3, 5, 9, 9, 12)]
    auto = compile_automaton(pats)
    text = make_text(rng, 1000, 3)[None, :]
    want = ac_states_ref(text[0], auto.classes, auto.delta, auto.n_classes)
    for kernel in (False, True):
        got = np.asarray(
            automaton_states(text, auto, seg=64, use_kernel=kernel)
        )[0]
        np.testing.assert_array_equal(got, want, err_msg=f"kernel={kernel}")


def test_automaton_caps_return_none():
    """Blowing the size caps degrades to None (callers keep the LUT path)."""
    pats = [np.arange(64, dtype=np.uint8) + i for i in range(4)]
    assert compile_automaton(pats, max_states=8) is None


def test_replicate_plans_with_automaton(rng):
    """Device replication moves the attached automaton with the plan."""
    pats = _dict_patterns(rng, 200, 8)
    buck = engine.compile_patterns(pats, bucket=True, automaton=True)
    dev = jax.local_devices()[0]
    rep = engine.replicate_plans(buck, dev)
    assert rep[0].automaton is not None
    text = _planted_text(rng, pats, 2048)
    idx = engine.build_index(text)
    np.testing.assert_array_equal(_counts(idx, buck), _counts(idx, rep))


# ---------------------------------------------------------------------------
# expansion-budget heuristic (satellite fix) + megascan gates
# ---------------------------------------------------------------------------


def test_expected_union_blocks_model(rng):
    """The block-level expectation is bounded by the total block count and
    scales with the STATIC popcount — unlike the old (B*n*P)>>kbits proxy,
    which at P=50k predicts ~24x more candidates than blocks exist."""
    pats = _dict_patterns(rng, 2000, 8)
    (plan,) = engine.compile_patterns(pats, automaton=False)
    B, n = 4, 1 << 20
    nblk = -(-n // engine.CAND_BLOCK)
    exp, rho = engine._expected_union_blocks(B, n, (plan,))
    assert 0 < exp <= B * nblk
    assert 0.0 < rho < 1.0
    # occupancy model: duplicates share slots, so popcount <= P
    assert plan.lut_pop <= plan.n_patterns
    old = (B * n * 50_000) >> engine.ENGINE_KBITS
    assert old > B * nblk, "the flat proxy over-shoots at dictionary scale"
    # more patterns -> monotonically denser
    (small,) = engine.compile_patterns(pats[:100], automaton=False)
    exp_s, rho_s = engine._expected_union_blocks(B, n, (small,))
    assert exp_s < exp and rho_s < rho


def test_shared_route_is_static_and_consistent(rng):
    """_shared_b_route derives one host-static decision; the probe reports
    exactly its budget/kind, so dispatcher and probe cannot disagree."""
    pats = _dict_patterns(rng, 1200, 8)
    buck = engine.compile_patterns(pats, bucket=True, automaton=True)
    text = make_text(np.random.RandomState(7), 1 << 16, 256)
    idx = engine.build_index(text)
    route = engine._shared_b_route(idx, buck)
    assert route.kind == "automaton"
    assert route.budget <= idx.batch * (-(-idx.n // engine.CAND_BLOCK))
    info = engine.route_probe(idx, buck)
    assert info["budget"] == route.budget
    assert info["kind"] == route.kind


def test_megascan_gates_dictionary_plans(rng):
    """P > MEGA_P_MAX and bucketed EPSMc plans are kernel-ineligible
    (spec=None -> pure-JAX fused fallback); small flat sets still build."""
    from repro.kernels.megascan.ops import MEGA_P_MAX

    big = engine.compile_patterns(
        _dict_patterns(rng, MEGA_P_MAX + 1, 8), automaton=False
    )
    assert build_mega_spec(big) is None
    bucketed_c = engine.compile_patterns(
        _dict_patterns(rng, 40, 16), bucket=True, automaton=False
    )
    assert bucketed_c[0].lut_bits is None
    assert build_mega_spec(bucketed_c) is None
    small = engine.compile_patterns(
        _dict_patterns(rng, 40, 8), bucket=False, automaton=False
    )
    assert build_mega_spec(small) is not None


def test_patternset_dictionary_passthrough(rng):
    """PatternSet(bucket=, automaton=) reaches the compiler; verdicts are
    unchanged."""
    pats = [bytes(p) for p in _dict_patterns(rng, 300, 8)]
    ps_flat = PatternSet(pats, bucket=False, automaton=False)
    ps_dict = PatternSet(pats, bucket=True, automaton=True)
    assert any(p.slot_off is not None for p in ps_dict.plans)
    assert any(p.automaton is not None for p in ps_dict.plans)
    doc = _planted_text(np.random.RandomState(3), [np.frombuffer(p, np.uint8) for p in pats], 4096)
    assert bool(ps_flat.contains_any(doc)) == bool(ps_dict.contains_any(doc))
    np.testing.assert_array_equal(
        np.asarray(ps_flat.count_each(doc)), np.asarray(ps_dict.count_each(doc))
    )


def test_compile_recorder_spans_and_gauges(rng):
    """Plan compilation reports its cost through repro.obs: a plan_compile
    span, per-group events, occupancy gauges, and the automaton build."""
    rec = Recorder(fence=False)
    pats = _dict_patterns(rng, 1100, 8)
    engine.compile_patterns(pats, recorder=rec)
    groups = rec.events_named("plan_group")
    assert len(groups) == 1 and groups[0]["n_patterns"] == 1100
    assert groups[0]["bucketed"] == 1
    assert rec.span_totals_ms().get("plan_compile", 0.0) > 0.0
    assert rec.events_named("automaton_built")
    g = rec.metrics.summary()["gauges"]
    assert any(k.startswith("plan.lut_occupancy") for k in g)
