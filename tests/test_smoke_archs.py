"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (deliverable f).
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch, reduced_config

LM_ARCHS = [a for a in ARCH_IDS if get_arch(a).family == "lm"]
RS_ARCHS = [a for a in ARCH_IDS if get_arch(a).family == "recsys"]


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke(rng, arch_id):
    from repro.models import transformer as tf

    cfg = reduced_config(arch_id)
    params = tf.init_params(jax.random.key(0), cfg)
    B, S = 2, 64
    tok = jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tok, "targets": jnp.roll(tok, -1, 1)}
    loss = tf.train_loss(params, cfg, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch_id
    # one SGD-ish step moves the loss
    g = jax.grad(lambda p: tf.train_loss(p, cfg, batch))(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch_id
    # decode path
    logits, kc, vc = tf.prefill(params, cfg, tok)
    assert logits.shape == (B, cfg.vocab)
    kc2, vc2 = tf.make_cache(cfg, B, S + 4, jnp.float32)
    kc2 = kc2.at[:, :, :S].set(kc.astype(kc2.dtype))
    vc2 = vc2.at[:, :, :S].set(vc.astype(vc2.dtype))
    lg, _, _ = tf.decode_step(
        params, cfg, jnp.argmax(logits, -1)[:, None], jnp.int32(S), kc2, vc2
    )
    assert lg.shape == (B, cfg.vocab) and np.isfinite(np.asarray(lg)).all()


def test_gnn_smoke(rng):
    from repro.data.graph import batched_molecules, edge_list, synthetic_graph
    from repro.models import gnn

    cfg = reduced_config("gatedgcn")
    params = gnn.init_params(jax.random.key(0), cfg)
    g = synthetic_graph(100, 6, cfg.d_feat, cfg.n_classes, seed=1)
    batch = {
        "nodes": jnp.asarray(g.feats),
        "edges": jnp.asarray(edge_list(g)),
        "labels": jnp.asarray(g.labels),
        "label_mask": jnp.ones((g.n_nodes,), jnp.float32),
    }
    loss = gnn.train_loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    logits = gnn.node_logits(params, cfg, batch)
    assert logits.shape == (100, cfg.n_classes)

    # molecule (graph readout) variant
    cfg_m = dataclasses.replace(cfg, readout="graph", d_edge_feat=4, d_feat=8)
    pm = gnn.init_params(jax.random.key(1), cfg_m)
    mb = batched_molecules(4, 10, 20, 8, 4, seed=2)
    mb = {k: jnp.asarray(v) for k, v in mb.items()}
    lm = gnn.train_loss(pm, cfg_m, mb, n_graphs=4)
    assert np.isfinite(float(lm))


@pytest.mark.parametrize("arch_id", RS_ARCHS)
def test_recsys_smoke(rng, arch_id):
    from repro.data.recsys_data import make_batch
    from repro.models import recsys as rs

    cfg = reduced_config(arch_id)
    params = rs.init_params(jax.random.key(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 16, seed=3).items()}
    loss = rs.train_loss(params, cfg, batch)
    assert np.isfinite(float(loss)), arch_id
    scores = rs.serve_scores(params, cfg, batch)
    assert scores.shape == (16,)
    assert np.all((np.asarray(scores) >= 0) & (np.asarray(scores) <= 1))
    g = jax.grad(lambda p: rs.train_loss(p, cfg, batch))(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch_id
    # retrieval path
    user = {k: v[:1] for k, v in batch.items() if k != "label"}
    cands = (
        batch["sparse"][:8]
        if cfg.kind == "dcn"
        else jnp.arange(8, dtype=jnp.int32)
    )
    sc = rs.retrieval_scores(params, cfg, user, cands)
    assert sc.shape == (8,) and np.isfinite(np.asarray(sc)).all()


def test_all_full_configs_instantiate():
    """The FULL assigned configs build (shapes only, no allocation)."""
    for arch_id in ARCH_IDS:
        spec = get_arch(arch_id)
        for shape_id in spec.shapes:
            cfg = spec.make_config(shape_id)
            assert cfg.name == arch_id
    # published param counts (within rounding of the model-card numbers)
    assert abs(get_arch("phi3.5-moe-42b-a6.6b").make_config().param_count() / 1e9 - 42) < 1
    assert abs(get_arch("phi3.5-moe-42b-a6.6b").make_config().active_param_count() / 1e9 - 6.6) < 0.3
    assert abs(get_arch("grok-1-314b").make_config().param_count() / 1e9 - 314) < 6
    assert abs(get_arch("yi-9b").make_config().param_count() / 1e9 - 8.8) < 0.5
    assert abs(get_arch("smollm-135m").make_config().param_count() / 1e6 - 135) < 10
