"""Streaming scan engine (core/stream.py): seam-equivalence against the
resident engine, the one-dispatch-per-chunk and bounded-device-memory
contracts, compressed (gzip/zstd) sources, the mid-stream prefix/start
injection the sharded scanner builds on, and the streaming consumers (epsm
stream= hatch, blocklist pipeline oversize documents, plan-cache hot key,
lazy stop-scanner sync)."""

import gzip
import io

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine, epsm
from repro.core.stream import Compressed, StreamScanner, find_stream, stream_count

from conftest import make_text

LENGTHS = (2, 4, 8, 13, 16, 32)
# few distinct chunk sizes -> few jit traces; odd values put the seams at
# unaligned, mid-beta-block offsets after the scanner's beta rounding
CHUNKS = (96, 251, 1000)


def _patterns(rng, text, k):
    """One extracted (guaranteed-hit) pattern per length, plus one random."""
    pats = []
    for m in LENGTHS:
        s = rng.randint(0, len(text) - m + 1)
        pats.append(text[s : s + m].copy())
        pats.append(rng.randint(0, 5, size=m).astype(np.uint8))
    return pats


def test_seam_equivalence_random_boundaries(rng):
    """Property suite: for random texts split at random chunk boundaries,
    streaming counts AND positions equal the whole-text resident engine for
    m in {2, 4, 8, 13, 16, 32} and k in {0, 1}."""
    for k in (0, 1):
        for trial in range(3):
            n = int(rng.randint(400, 3000))
            text = make_text(rng, n, 4)
            pats = _patterns(rng, text, k)
            plans = engine.compile_patterns(pats, k=k)
            idx = engine.build_index(text)
            want_counts = np.asarray(engine.count_many_jit(idx, plans, k=k))[0]
            want_mask = np.asarray(engine.match_many_jit(idx, plans, k=k))[0]
            chunk = int(CHUNKS[trial % len(CHUNKS)])
            sc = StreamScanner(plans, chunk, k=k)
            got = sc.count_many(text)
            np.testing.assert_array_equal(
                got, want_counts, err_msg=f"k={k} chunk={chunk} n={n}"
            )
            pos = StreamScanner(plans, chunk, k=k).positions_many(text)
            for p_i in range(len(pos)):
                np.testing.assert_array_equal(
                    pos[p_i], np.nonzero(want_mask[p_i])[0],
                    err_msg=f"k={k} chunk={chunk} pattern row {p_i}",
                )


def test_seam_occurrence_straddles_every_phase():
    """Planted occurrences crossing a chunk seam at EVERY straddle phase
    (first byte in chunk i, last byte in chunk i+1, and everything between)
    are found exactly once — including starts inside a beta block and starts
    inside the final chunk's padding region."""
    for m in (2, 4, 8, 13, 16, 32):
        pat = np.full(m, 9, np.uint8)  # alphabet disjoint from the text
        plans = engine.compile_patterns([pat])
        sc = StreamScanner(plans, 256)
        step = sc.step_bytes
        text = make_text(np.random.RandomState(m), 3 * step + 11, 4)
        # every start that makes the occurrence touch the first seam, plus
        # one deep inside the (short, padded) final chunk
        starts = [step - m + 1 + j for j in range(m + 1) if step - m + 1 + j >= 0]
        starts += [2 * step + 5]
        starts = sorted(
            {s for s in starts if 0 <= s <= len(text) - m}
        )
        # plant with a >= 1 byte gap: abutting all-9 plants would merge into
        # a run with extra (unplanned) occurrences of the all-9 pattern
        planted, last_end = [], -1
        for s in starts:
            if s > last_end:
                text[s : s + m] = pat
                planted.append(s)
                last_end = s + m
        got = StreamScanner(plans, 256).count_many(text)
        assert got.tolist() == [len(planted)], f"m={m}"
        pos = StreamScanner(plans, 256).positions_many(text)
        np.testing.assert_array_equal(pos[0], np.asarray(planted), f"m={m}")


def test_fused_seam_equals_reference_two_pass(rng):
    """The fused chunk step (count_many(..., end_min=prev_ov), one scan, no
    overlap-prefix sub-index) is bit-identical to the reference two-pass
    subtraction across the full seam property grid: m in {2,4,8,13,16,32},
    k in {0,1}, every chunk size — counts AND positions."""
    for k in (0, 1):
        for trial in range(3):
            n = int(rng.randint(400, 3000))
            text = make_text(rng, n, 4)
            pats = _patterns(rng, text, k)
            plans = engine.compile_patterns(pats, k=k)
            chunk = int(CHUNKS[trial % len(CHUNKS)])
            ref = StreamScanner(plans, chunk, k=k, fused=False)
            want = ref.count_many(text)
            got = StreamScanner(plans, chunk, k=k, fused=True).count_many(text)
            np.testing.assert_array_equal(
                got, want, err_msg=f"k={k} chunk={chunk} n={n}"
            )
            pos_ref = StreamScanner(
                plans, chunk, k=k, fused=False
            ).positions_many(text)
            pos_fused = StreamScanner(
                plans, chunk, k=k, fused=True
            ).positions_many(text)
            for r in range(len(pos_ref)):
                np.testing.assert_array_equal(
                    pos_fused[r], pos_ref[r],
                    err_msg=f"k={k} chunk={chunk} row {r}",
                )


def test_mixed_plans_one_dispatch_per_chunk_shared_path(rng, monkeypatch):
    """Regression (ISSUE 6 satellite): a MIXED plan set — one sparse-eligible
    EPSMb group among a/c groups — must still issue exactly ONE jitted
    dispatch per chunk with counts equal to the per-group reference, i.e.
    the single-eligible-group case routes through _count_groups_b_shared
    instead of silently taking the slow per-group path."""
    monkeypatch.setattr(engine, "SPARSE_B_MIN_ELEMS", 0)
    text = make_text(rng, 6_000, 4)
    pats = [
        text[7:9].copy(),        # EPSMa
        text[100:108].copy(),    # the ONE sparse-eligible EPSMb group
        text[200:208].copy(),    # (>= 4 patterns: eligibility floor)
        text[400:408].copy(),
        text[900:908].copy(),
        text[300:324].copy(),    # EPSMc
    ]
    plans = engine.compile_patterns(pats)
    idx = engine.build_index(text)
    assert (
        sum(
            1
            for p in plans
            if p.regime == "b" and engine._sparse_b_eligible(idx, p)
        )
        == 1
    )
    # single eligible group still counts through the shared pass
    calls = []
    orig = engine._count_groups_b_shared

    def spy(index, plans_, bank, end_min=None):
        calls.append(len(plans_))
        return orig(index, plans_, bank, end_min)

    monkeypatch.setattr(engine, "_count_groups_b_shared", spy)
    counts = np.asarray(engine.count_many(idx, plans))
    assert calls == [1]  # routed through the shared candidate pass
    for row, pid in enumerate(engine.plan_order(plans)):
        want = int(np.asarray(epsm.find(text, pats[pid])).sum())
        assert counts[0, row] == want, f"pattern {pid}"
    # and the streaming loop stays at exactly one dispatch per chunk
    sc = StreamScanner(plans, 1024)
    n_windows = sum(1 for _ in sc._windows(text))
    got = sc.count_many(text)
    assert sc.dispatch_count == n_windows
    for row, pid in enumerate(sc.order):
        want = int(np.asarray(epsm.find(text, pats[pid])).sum())
        assert got[row] == want, f"pattern {pid}"


def test_auto_chunk_bytes_resolved_and_exact(rng):
    """chunk_bytes="auto" resolves to a sane, beta-aligned size (memory
    budget + dispatch-overhead probe), is recorded on the scanner, and scans
    exactly."""
    from repro.core.epsm import EPSMC_BETA
    from repro.core.stream import (
        MAX_CHUNK_BYTES,
        MIN_CHUNK_BYTES,
        auto_chunk_bytes,
    )

    auto = auto_chunk_bytes()
    assert MIN_CHUNK_BYTES <= auto <= MAX_CHUNK_BYTES
    assert auto % EPSMC_BETA == 0
    text = make_text(rng, 5_000, 4)
    plans = engine.compile_patterns([text[100:108].copy()])
    sc = StreamScanner(plans)  # default chunk_bytes="auto"
    assert sc.chunk_bytes == auto
    want = StreamScanner(plans, 512).count_many(text)
    np.testing.assert_array_equal(sc.count_many(text), want)


def test_one_dispatch_per_chunk_and_bounded_window(rng):
    text = make_text(rng, 10_000, 4)
    plans = engine.compile_patterns([text[50:58].copy(), text[300:316].copy()])
    sc = StreamScanner(plans, 1024)
    n_windows = sum(1 for _ in sc._windows(text))
    sc.count_many(text)
    assert sc.dispatch_count == n_windows  # exactly one jitted call per chunk
    # device footprint is O(chunk), independent of the input length
    assert sc.window_bytes < 2 * 1024 + sc.overlap + 8
    assert sc.device_bytes_per_chunk < 64 * (1 << 17) + 32 * sc.window_bytes


def test_sources_bytes_file_iterable_agree(rng):
    text = make_text(rng, 5_000, 4)
    plans = engine.compile_patterns([text[100:108].copy()])
    sc = StreamScanner(plans, 512)
    want = sc.count_many(text)
    as_bytes = sc.count_many(text.tobytes())
    as_file = sc.count_many(io.BytesIO(text.tobytes()))
    ragged = np.array_split(text, [1, 7, 8, 1000, 1001, 4000])
    as_iter = sc.count_many(iter(ragged))
    assert want.tolist() == as_bytes.tolist() == as_file.tolist() == as_iter.tolist()


def test_empty_and_short_sources(rng):
    plans = engine.compile_patterns([np.arange(8, dtype=np.uint8)])
    sc = StreamScanner(plans, 256)
    assert sc.count_many(b"").tolist() == [0]
    assert sc.dispatch_count == 0  # no chunk, no dispatch
    short = np.arange(8, dtype=np.uint8)
    assert StreamScanner(plans, 256).count_many(short).tolist() == [1]
    assert StreamScanner(plans, 256).count_many(short[:5]).tolist() == [0]


def test_gzip_sources_stream_exactly(rng):
    """Compressed sources decompress incrementally into the O(chunk) window:
    bytes, file-like, and an iterator of frames, single- and multi-member,
    all agree with the plain scan — including occurrences planted ACROSS
    gzip member boundaries (the decompressed-chunk seams land mid-window,
    so the overlap carry is exercised by the frame layout itself)."""
    text = make_text(rng, 30_000, 4)
    m = 8
    pat = np.full(m, 9, np.uint8)
    cuts = [5_000, 12_344, 20_008]  # member boundaries
    for cut in cuts:
        text[cut - m // 2 : cut - m // 2 + m] = pat  # straddles the boundary
    plans = engine.compile_patterns([pat, text[100:108].copy()])
    want = StreamScanner(plans, 1024).count_many(text)
    assert want[0] >= len(cuts)  # the straddling plants are really there
    members = np.split(text, cuts)
    blob_one = gzip.compress(text.tobytes())
    blob_multi = b"".join(gzip.compress(c.tobytes()) for c in members)
    frames = [gzip.compress(c.tobytes()) for c in members]
    for src in (
        Compressed(blob_one),
        Compressed(blob_multi),
        Compressed(io.BytesIO(blob_multi)),
        Compressed(iter(frames), codec="gzip"),
    ):
        got = StreamScanner(plans, 1024).count_many(src)
        np.testing.assert_array_equal(got, want)
    # positions agree too (mask path shares the decompression)
    pos = StreamScanner(plans, 1024).positions_many(Compressed(blob_multi))
    want_pos = StreamScanner(plans, 1024).positions_many(text)
    for r in range(len(pos)):
        np.testing.assert_array_equal(pos[r], want_pos[r])
    # truncated stream is an error, not a silent short count
    with pytest.raises(ValueError):
        StreamScanner(plans, 1024).count_many(Compressed(blob_one[:-20]))
    # auto-sniff survives a first read() piece shorter than the magic
    tiny_pieces = [blob_one[:2], blob_one[2:3], blob_one[3:]]
    got = StreamScanner(plans, 1024).count_many(Compressed(iter(tiny_pieces)))
    np.testing.assert_array_equal(got, want)


def test_zstd_sources_stream_exactly(rng):
    zstandard = pytest.importorskip("zstandard")
    text = make_text(rng, 20_000, 4)
    plans = engine.compile_patterns([text[100:108].copy()])
    want = StreamScanner(plans, 1024).count_many(text)
    cctx = zstandard.ZstdCompressor()
    blob = b"".join(
        cctx.compress(c.tobytes()) for c in np.array_split(text, 4)
    )
    got = StreamScanner(plans, 1024).count_many(Compressed(blob))
    np.testing.assert_array_equal(got, want)
    got_auto = StreamScanner(plans, 1024).count_many(
        Compressed(io.BytesIO(blob), codec="auto")
    )
    np.testing.assert_array_equal(got_auto, want)


def test_mid_stream_prefix_start_injection(rng):
    """The factored chunk loop: scanning [0, p) and [p, n) as separate
    ranges (the second with the carried prefix and start offset) composes to
    the whole-text result — counts add, positions are global and disjoint.
    This is the per-shard contract shard_stream.py relies on."""
    text = make_text(rng, 9_000, 4)
    pats = [text[70:78].copy(), text[10:42].copy()]
    plans = engine.compile_patterns(pats)
    sc = StreamScanner(plans, 1024)
    ov = sc.overlap
    whole = sc.count_many(text)
    whole_pos = StreamScanner(plans, 1024).positions_many(text)
    for p in (1024, 2048, 4096):  # beta-aligned split points
        left = StreamScanner(plans, 1024).count_many(text[:p])
        right = StreamScanner(plans, 1024).count_many(
            text[p:], prefix=text[p - ov : p], start=p
        )
        np.testing.assert_array_equal(left + right, whole, err_msg=f"p={p}")
        pos_l = StreamScanner(plans, 1024).positions_many(text[:p])
        pos_r = StreamScanner(plans, 1024).positions_many(
            text[p:], prefix=text[p - ov : p], start=p
        )
        for r in range(len(pos_l)):
            np.testing.assert_array_equal(
                np.concatenate([pos_l[r], pos_r[r]]), whole_pos[r],
                err_msg=f"p={p} row {r}",
            )
    # contract violations are loud
    with pytest.raises(ValueError):  # start - len(prefix) off the beta grid
        StreamScanner(plans, 1024).count_many(text[5:], prefix=text[1:5], start=5)
    with pytest.raises(ValueError):  # prefix longer than the overlap
        StreamScanner(plans, 1024).count_many(
            text[ov + 8 :], prefix=text[: ov + 8], start=ov + 8
        )


def test_stream_count_original_order_and_find_stream(rng):
    text = make_text(rng, 20_000, 4)
    pats = [text[70:102].copy(), text[10:12].copy(), text[500:508].copy()]
    got = stream_count(text, pats, chunk_bytes=777)
    for i, p in enumerate(pats):
        assert got[i] == int(np.asarray(epsm.count(text, p))), i
    mask = find_stream(text, pats[2], chunk_bytes=777)
    np.testing.assert_array_equal(mask, np.asarray(epsm.find(text, pats[2])))


def test_epsm_stream_escape_hatch(rng, monkeypatch):
    """find/count with stream=True (and the auto threshold) are identical to
    the resident scan."""
    text = make_text(rng, 9_000, 4)
    pat = text[123:131].copy()
    want_mask = np.asarray(epsm.find(text, pat))
    want_count = int(np.asarray(epsm.count(text, pat)))
    np.testing.assert_array_equal(epsm.find(text, pat, stream=True), want_mask)
    assert int(epsm.count(text, pat, stream=True)) == want_count
    assert int(epsm.count(text, pat, k=1, stream=True)) == int(
        np.asarray(epsm.count(text, pat, k=1))
    )
    # auto mode: host texts above the threshold stream without being asked
    monkeypatch.setattr(epsm, "STREAM_AUTO_BYTES", 1024)
    auto = epsm.find(text, pat)
    assert isinstance(auto, np.ndarray)  # host mask: the streaming path ran
    np.testing.assert_array_equal(auto, want_mask)
    np.testing.assert_array_equal(
        epsm.positions(text, pat), np.nonzero(want_mask)[0]
    )


def test_pipeline_oversize_docs_stream(rng, monkeypatch):
    """Oversize documents take the bounded-memory streaming path and still
    get exact blocklist verdicts."""
    from repro.data import pipeline as pl

    monkeypatch.setattr(pl, "MAX_FILTER_LEN", 512)
    bad = b"\x07\x01\x07\x02\x07\x03"
    clean_big = make_text(rng, 4_000, 4)
    dirty_big = make_text(rng, 4_000, 4)
    dirty_big[2_345 : 2_345 + len(bad)] = np.frombuffer(bad, np.uint8)
    small = make_text(rng, 100, 4)
    pipe = pl.LMDataPipeline(
        [clean_big, dirty_big, small], seq_len=64, batch_size=1,
        blocklist=[bad, b"\x06\x06\x06\x06\x06\x06\x06\x06"],
    )
    for _ in pipe:
        pass
    assert pipe.stats.docs_in == 3
    assert pipe.stats.docs_blocked == 1  # dirty_big, found by the scanner
    assert pipe.stats.docs_out == 2


def test_plan_cache_hit_no_device_transfer(monkeypatch):
    """compile_patterns_cached: a repeat call with the same live device
    arrays must not touch the device — the memoized digest answers."""
    pats = [
        jnp.asarray(np.frombuffer(b"streaming!", np.uint8)),
        jnp.asarray(np.frombuffer(b"does not sync", np.uint8)),
    ]
    first = engine.compile_patterns_cached(pats)  # warm: digests + plans
    transfers = []
    orig = jax.device_get

    def counting_get(x):
        transfers.append(type(x).__name__)
        return orig(x)

    monkeypatch.setattr(jax, "device_get", counting_get)
    again = engine.compile_patterns_cached(pats)
    assert transfers == []  # zero device transfers on the hot path
    assert again is first  # and it really was a cache hit


def test_stop_scanner_lazy_sync_identical(rng, monkeypatch):
    """StopScanner with the scalar-gated transfer: hit matrices identical to
    the naive scan, and the (B, P) device_get happens ONLY on steps with at
    least one hit."""
    from repro.serve.engine import StopScanner

    stops = [b"\x00\x01", b"\x01\x02\x00"]
    stream = bytes(rng.randint(0, 3, size=60).astype(np.uint8))
    sc = StopScanner(stops, 1, len(stream))
    transfers = []
    orig = jax.device_get

    def counting_get(x):
        transfers.append(1)
        return orig(x)

    monkeypatch.setattr(jax, "device_get", counting_get)
    hit_steps = []
    for step in range(len(stream)):
        row = sc.scan(np.asarray([stream[step]], np.int32), step)[0]
        want = np.asarray(
            [
                step >= len(s) - 1 and stream[step - len(s) + 1 : step + 1] == s
                for s in stops
            ]
        )
        np.testing.assert_array_equal(row, want, err_msg=f"step {step}")
        if want.any():
            hit_steps.append(step)
    assert sc.dispatch_count == len(stream)
    assert len(transfers) == len(hit_steps)  # matrix synced only on hits
    assert len(hit_steps) > 0  # the gate was actually exercised both ways


def test_compressed_sources_under_injected_faults(rng):
    """The fault harness x compression matrix: a truncation that cuts a
    gzip/zstd frame mid-member surfaces as the decompressor's truncated-
    stream ValueError, an injected read error surfaces as-is, and a
    zero-rate plan is a clean pass-through — never a silent short count."""
    from repro.dist.fault_injection import FaultPlan, FaultyChunkSource, InjectedReadError

    text = make_text(rng, 30_000, 4)
    plans = engine.compile_patterns([text[100:108].copy(), text[5:7].copy()])
    want = StreamScanner(plans, 1024).count_many(text)

    blobs = {"gzip": gzip.compress(text.tobytes())}
    try:
        import zstandard

        blobs["zstd"] = zstandard.ZstdCompressor().compress(text.tobytes())
    except ImportError:
        pass

    for codec, blob in blobs.items():
        # single member: every proper prefix is a truncated stream
        pieces = [blob[i : i + 1000] for i in range(0, len(blob), 1000)]

        clean = FaultPlan(0)  # all rates zero: the wrapper is transparent
        got = StreamScanner(plans, 1024).count_many(
            Compressed(FaultyChunkSource(iter(pieces), clean), codec=codec)
        )
        np.testing.assert_array_equal(got, want, err_msg=codec)

        trunc = FaultPlan(1, truncate_rate=1.0, attempts_per_fault=None)
        with pytest.raises(ValueError, match="truncated"):
            StreamScanner(plans, 1024).count_many(
                Compressed(FaultyChunkSource(iter(pieces), trunc), codec=codec)
            )
        assert any(e.action == "truncate" for e in trunc.events)

        # mid-member read error: make the SECOND piece fail so decompression
        # is already underway when the fault lands
        err = FaultPlan(2, read_error_rate=1.0, attempts_per_fault=1)
        with pytest.raises(InjectedReadError):
            err.check("read", ("stream", 0))  # burn piece 0's transient fault
        with pytest.raises(InjectedReadError):
            StreamScanner(plans, 1024).count_many(
                Compressed(FaultyChunkSource(iter(pieces), err), codec=codec)
            )

        # truncated compressed data is NOT retryable: rescanning the same
        # bytes can't help, so the classifier must fail fast
        from repro.dist.fault_tolerance import default_is_retryable

        assert not default_is_retryable(ValueError(f"truncated {codec} stream"))
        assert default_is_retryable(InjectedReadError("flaky socket"))


def test_stream_watchdog_flags_stalled_chunk(rng):
    """StreamScanner(watchdog=...) times each host step; a source that
    stalls mid-stream raises StragglerAbort under policy="raise", and under
    policy="log" the scan completes exactly with the event reported to
    on_straggler."""
    import time as _time

    from repro.dist.fault_tolerance import StepWatchdog, StragglerAbort

    text = make_text(rng, 40_000, 4)
    plans = engine.compile_patterns([text[100:108].copy()])
    want = StreamScanner(plans, 1024).count_many(text)

    def stalling_chunks(stall_s):
        def gen():
            for i in range(0, len(text), 1024):
                if i == 20_480:  # enough history for the rolling median
                    _time.sleep(stall_s)
                yield text[i : i + 1024]

        return gen()

    wd = StepWatchdog(factor=5.0, policy="raise", min_history=3)
    with pytest.raises(StragglerAbort):
        StreamScanner(plans, 1024, watchdog=wd).count_many(stalling_chunks(0.25))

    seen = []
    wd2 = StepWatchdog(factor=5.0, policy="log", min_history=3)
    got = StreamScanner(
        plans, 1024, watchdog=wd2, on_straggler=seen.append
    ).count_many(stalling_chunks(0.25))
    np.testing.assert_array_equal(got, want)  # logging never changes the scan
    assert seen and seen[0].duration_s > seen[0].median_s
    assert wd2.events == seen

    # no watchdog, no timing: the plain path is untouched
    got_plain = StreamScanner(plans, 1024).count_many(stalling_chunks(0.0))
    np.testing.assert_array_equal(got_plain, want)
