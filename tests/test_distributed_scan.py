"""Distributed packed scan: 1-device mesh in-process, 8 fake devices via
subprocess (jax device count is locked at first init, so multi-device tests
must run in their own interpreter)."""

import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import baselines, distributed
from repro.dist.compat import make_mesh

from conftest import make_text


def test_single_device_mesh(rng):
    mesh = make_mesh((1,), ("data",))
    t = make_text(rng, 1024, 4)
    p = t[100:108].copy()
    f = distributed.make_distributed_find(mesh, "data")
    got = np.asarray(f(jnp.asarray(t), jnp.asarray(p)))
    np.testing.assert_array_equal(got, baselines.naive_np(t, p))
    c = distributed.make_distributed_count(mesh, "data")
    assert int(c(jnp.asarray(t), jnp.asarray(p))) == baselines.naive_np(t, p).sum()


MULTI_DEV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from repro.core import distributed, baselines
from repro.dist.compat import make_mesh

rng = np.random.RandomState(42)
n = 8 * 512
t = rng.randint(0, 4, size=n).astype(np.uint8)

mesh = make_mesh((8,), ("data",))
for m in [1, 2, 9, 17, 32]:
    s = rng.randint(0, n - m)
    p = t[s:s+m].copy()
    oracle = baselines.naive_np(t, p)
    f = distributed.make_distributed_find(mesh, "data")
    got = np.asarray(f(jnp.asarray(t), jnp.asarray(p)))
    assert np.array_equal(got, oracle), ("find", m)
    c = distributed.make_distributed_count(mesh, "data")
    assert int(c(jnp.asarray(t), jnp.asarray(p))) == oracle.sum(), ("count", m)

mesh2 = make_mesh((2, 4), ("pod", "data"))
for m in [3, 9, 20]:
    s = rng.randint(0, n - m)
    p = t[s:s+m].copy()
    oracle = baselines.naive_np(t, p)
    f = distributed.make_distributed_find(mesh2, ("pod", "data"))
    got = np.asarray(f(jnp.asarray(t), jnp.asarray(p)))
    assert np.array_equal(got, oracle), ("2axis", m)
print("DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_multi_device_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", MULTI_DEV_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo",
    )
    assert "DISTRIBUTED_OK" in res.stdout, res.stdout + res.stderr
