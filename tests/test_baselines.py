"""Baseline algorithms (the paper's competitors) against the scalar oracle."""

import numpy as np
import pytest

from repro.core import baselines

from conftest import extract_pattern, make_text


@pytest.mark.parametrize("name", sorted(baselines.BASELINES))
@pytest.mark.parametrize("sigma", [2, 4, 20, 256])
def test_baseline_matches_oracle(rng, name, sigma):
    fn = baselines.BASELINES[name]
    n = 1500
    t = make_text(rng, n, sigma)
    for m in [1, 2, 3, 4, 8, 16, 24, 31]:
        if name == "hash3" and m < 3:
            continue
        p = extract_pattern(rng, t, m)
        oracle = baselines.naive_np(t, p)
        got = np.asarray(fn(t, p))
        np.testing.assert_array_equal(got, oracle, err_msg=f"{name} m={m}")


def test_shift_or_m32(rng):
    t = make_text(rng, 800, 4)
    p = extract_pattern(rng, t, 32)
    np.testing.assert_array_equal(
        np.asarray(baselines.shift_or(t, p)), baselines.naive_np(t, p)
    )
    with pytest.raises(ValueError):
        baselines.shift_or(t, make_text(rng, 33, 4))


def test_bndm_limit(rng):
    t = make_text(rng, 100, 4)
    with pytest.raises(ValueError):
        baselines.bndm(t, make_text(rng, 32, 4))


def test_periodic_patterns_all_baselines(rng):
    t = np.tile(np.array([7, 7, 9], dtype=np.uint8), 100)
    for name, fn in baselines.BASELINES.items():
        for m in [3, 6, 9]:
            p = t[:m].copy()
            oracle = baselines.naive_np(t, p)
            np.testing.assert_array_equal(
                np.asarray(fn(t, p)), oracle, err_msg=f"{name} m={m}"
            )
