"""Benchmark-drift gate (benchmarks/render_tables.py): the committed JSONs
satisfy their schemas, the renderer is deterministic, and schema violations
actually fail — so CI's benchgate job can be trusted to catch drift."""

import json
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks import render_tables as rt  # noqa: E402

OUTDIR = REPO / "experiments" / "benchmarks"


def test_committed_jsons_validate_and_render():
    text = rt.render(OUTDIR)  # raises SchemaError on any violation
    assert text == rt.render(OUTDIR)  # deterministic
    for f in OUTDIR.glob("BENCH_*.json"):
        if f.name != rt.PAPER_JSON:
            assert f.name in text  # every artifact is surfaced in the md


def test_committed_markdown_is_fresh():
    md = OUTDIR / rt.MD_NAME
    assert md.exists(), "paper_tables.md missing"
    assert md.read_text() == rt.render(OUTDIR), (
        "experiments/benchmarks/paper_tables.md is stale — run "
        "`python benchmarks/render_tables.py`"
    )


def test_schema_violations_raise(tmp_path):
    doc = json.loads((OUTDIR / "BENCH_multipattern.json").read_text())
    rows, _ = rt.split_meta("BENCH_multipattern.json", doc)
    good = dict(rows[0])
    for corruption in (
        {"us_per_call": None},
        {"GBps": float("nan")},
        {"size_bytes": 0},
        {"name": 7},
    ):
        with pytest.raises(rt.SchemaError):
            rt.validate_rows("BENCH_multipattern.json", [dict(good, **corruption)])
    with pytest.raises(rt.SchemaError):
        rt.validate_rows("BENCH_multipattern.json", [])
    bad = dict(good)
    del bad["speedup_vs_vmap"]  # file-specific required field
    with pytest.raises(rt.SchemaError):
        rt.validate_rows("BENCH_multipattern.json", [bad])
    with pytest.raises(rt.SchemaError):
        rt.validate_paper(rt.PAPER_JSON, {"tables": {}})


def test_meta_wrapper_split_and_rendered():
    """BENCH_*.json may be {"meta": {...}, "rows": [...]} — meta carries
    measurement caveats (host cores, baseline identity) and must surface in
    the rendered markdown; malformed meta raises."""
    rows, meta = rt.split_meta("BENCH_shard.json", {"meta": {"host_cores": 2},
                                                    "rows": [{"x": 1}]})
    assert rows == [{"x": 1}] and meta == {"host_cores": 2}
    rows, meta = rt.split_meta("BENCH_shard.json", [{"x": 1}])
    assert rows == [{"x": 1}] and meta == {}
    with pytest.raises(rt.SchemaError):
        rt.split_meta("BENCH_shard.json", {"meta": 3, "rows": []})
    doc = json.loads((OUTDIR / "BENCH_shard.json").read_text())
    rows, meta = rt.split_meta("BENCH_shard.json", doc)
    assert meta.get("host_cores"), "BENCH_shard meta must record host_cores"
    table = rt.format_rows_table("BENCH_shard.json", rows, meta)
    assert "host_cores" in table


def test_check_mode_detects_drift(tmp_path):
    for f in OUTDIR.glob("BENCH_*.json"):
        (tmp_path / f.name).write_text(f.read_text())
    assert rt.main(["--dir", str(tmp_path)]) == 0  # writes fresh md
    assert rt.main(["--dir", str(tmp_path), "--check"]) == 0
    md = tmp_path / rt.MD_NAME
    md.write_text(md.read_text() + "drift\n")
    assert rt.main(["--dir", str(tmp_path), "--check"]) == 2
