"""Shared test fixtures.

NOTE: tests run with the real single CPU device — the 512-device
XLA_FLAGS override belongs ONLY to launch/dryrun.py (and subprocesses
spawned by the multi-device tests), never here.
"""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0xC0FFEE)


def make_text(rng, n, sigma):
    return rng.randint(0, sigma, size=n).astype(np.uint8)


def extract_pattern(rng, text, m):
    s = rng.randint(0, len(text) - m + 1)
    return text[s : s + m].copy()


@pytest.fixture
def texts(rng):
    """(name, text) pairs mimicking the paper's corpora at test scale."""
    return {
        "genome": make_text(rng, 4096, 4),
        "protein": make_text(rng, 4096, 20),
        "english": make_text(rng, 4096, 64),
        "binary": make_text(rng, 4096, 2),
    }
