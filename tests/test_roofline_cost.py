"""Roofline machinery: jaxpr cost walker vs XLA cost analysis on unrolled
probes (where HLO analysis is exact), and the while-aware collective parser."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.hlo_collectives import collective_stats
from repro.analysis.jaxpr_cost import step_cost
from repro.dist.compat import make_mesh
from repro.analysis.roofline import collective_bytes, roofline_terms


def test_walker_matches_unrolled_hlo():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=16, unroll=16)
        return h

    from repro.dist.compat import cost_analysis_dict

    args = (jax.ShapeDtypeStruct((128, 256), jnp.float32),
            jax.ShapeDtypeStruct((256, 256), jnp.float32))
    hlo_flops = cost_analysis_dict(jax.jit(f).lower(*args).compile())["flops"]
    est = step_cost(f, *args)
    assert abs(est["flops"] - hlo_flops) / hlo_flops < 0.05


def test_walker_multiplies_scan_trip_count():
    def probe(L):
        def f(x, w):
            def body(h, _):
                return h @ w, None
            h, _ = jax.lax.scan(body, x, None, length=L)
            return h
        return step_cost(
            f,
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
        )["mxu_flops"]

    assert probe(16) == 2 * probe(8)


def test_walker_counts_remat():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        body_r = jax.checkpoint(body)
        h, _ = jax.lax.scan(body_r, x, None, length=4)
        return jnp.sum(h)

    args = (jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32))
    fwd = step_cost(f, *args)["mxu_flops"]
    grad = step_cost(lambda x, w: jax.grad(lambda ww: f(x, ww))(w), *args)["mxu_flops"]
    # bwd with remat: recompute fwd (1x) + two transpose matmuls (2x) => ~4x fwd
    assert 3.4 <= grad / fwd <= 4.6, grad / fwd


def test_collective_parser_multiplies_while_trips():
    """Collectives inside a scanned body must be scaled by trip count."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_mesh((1,), ("data",))
    L = 8

    def f(x, w):
        def body(h, _):
            h = h @ w
            h = jax.lax.with_sharding_constraint(h, NamedSharding(mesh, P()))
            return h, None
        h, _ = jax.lax.scan(body, x, None, length=L)
        return h.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    xs = NamedSharding(mesh, P("data", None))
    with mesh:
        compiled = jax.jit(f, in_shardings=(xs, None)).lower(x, w).compile()
    stats = collective_stats(compiled.as_text())
    total = sum(s["count"] for s in stats.values())
    # single-device mesh => no collectives expected; parser must not crash
    assert total >= 0


def test_roofline_term_classification():
    t = roofline_terms(197e12, 0.0, 0.0)  # exactly 1s of MXU work
    assert t["bottleneck"] == "compute"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    t = roofline_terms(0.0, 819e9 * 2, 0.0)
    assert t["bottleneck"] == "memory" and abs(t["memory_s"] - 2.0) < 1e-9
    t = roofline_terms(0.0, 0.0, 50e9)
    assert t["bottleneck"] == "collective"


@pytest.mark.slow
def test_collective_parser_on_multidevice_scan():
    """With 8 fake devices (subprocess), a psum inside an L-trip scan must be
    counted L times."""
    import os
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis.hlo_collectives import collective_stats
from repro.dist.compat import make_mesh
mesh = make_mesh((8,), ("data",))
L = 8

def f(x, w):
    def body(h, _):
        h = h @ w  # w sharded on contraction dim => all-reduce per trip
        return h, None
    h, _ = jax.lax.scan(body, x, None, length=L)
    return h

x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
with mesh:
    compiled = jax.jit(
        f,
        in_shardings=(NamedSharding(mesh, P(None, "data")),
                      NamedSharding(mesh, P("data", None))),
        out_shardings=NamedSharding(mesh, P(None, None)),
    ).lower(x, w).compile()
stats = collective_stats(compiled.as_text())
n = sum(s["count"] for s in stats.values())
assert n >= L, f"expected >= {L} collectives, parsed {n}"
print("COLL_OK", n)
"""
    res = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd="/root/repo",
    )
    assert "COLL_OK" in res.stdout, res.stdout + res.stderr
