"""Per-kernel interpret-mode validation against the pure-jnp oracles.

Sweeps text sizes (including non-tile-aligned), alphabets and pattern
lengths, per the kernel-testing contract.
"""

import numpy as np
import pytest

from repro.core import baselines
from repro.kernels.epsma import epsma as k_epsma
from repro.kernels.epsma import epsma_ref
from repro.kernels.epsmb import epsmb as k_epsmb
from repro.kernels.epsmb import epsmb_ref
from repro.kernels.epsmc import epsmc as k_epsmc
from repro.kernels.epsmc import epsmc_ref

from conftest import extract_pattern, make_text

SIZES = [1, 100, 4095, 4096, 4097, 12289]
SIGMAS = [2, 4, 256]


@pytest.mark.parametrize("sigma", SIGMAS)
@pytest.mark.parametrize("n", SIZES)
def test_epsma_kernel_sweep(rng, sigma, n):
    t = make_text(rng, n, sigma)
    for m in [1, 2, 3]:
        if m > n:
            continue
        p = extract_pattern(rng, t, m)
        got = np.asarray(k_epsma(t, p))
        ref = np.asarray(epsma_ref(t, p))
        np.testing.assert_array_equal(got, ref, err_msg=f"n={n} m={m}")
        np.testing.assert_array_equal(got, baselines.naive_np(t, p))


@pytest.mark.parametrize("sigma", SIGMAS)
@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("fuse_verify", [True, False])
def test_epsmb_kernel_sweep(rng, sigma, n, fuse_verify):
    t = make_text(rng, n, sigma)
    for m in [4, 5, 8, 15]:
        if m > n:
            continue
        p = extract_pattern(rng, t, m)
        got = np.asarray(k_epsmb(t, p, fuse_verify=fuse_verify))
        ref = np.asarray(epsmb_ref(t, p))
        np.testing.assert_array_equal(got, ref, err_msg=f"n={n} m={m}")


@pytest.mark.parametrize("sigma", SIGMAS)
@pytest.mark.parametrize("n", [100, 4097, 12289])
def test_epsmc_kernel_sweep(rng, sigma, n):
    t = make_text(rng, n, sigma)
    for m in [16, 17, 24, 32, 48, 64]:
        if m > n:
            continue
        p = extract_pattern(rng, t, m)
        got = np.asarray(k_epsmc(t, p))
        ref = np.asarray(epsmc_ref(t, p))
        np.testing.assert_array_equal(got, ref, err_msg=f"n={n} m={m}")


def test_epsma_small_tile(rng):
    # a tile much smaller than the text exercises many grid programs
    t = make_text(rng, 2000, 4)
    p = extract_pattern(rng, t, 3)
    got = np.asarray(k_epsma(t, p, tile=128))
    np.testing.assert_array_equal(got, baselines.naive_np(t, p))


def test_epsmb_small_tile_boundary_matches(rng):
    # force occurrences that straddle tile boundaries
    t = make_text(rng, 1024, 4)
    m = 8
    for s in [120, 127, 128, 250, 255, 256]:
        p = t[s : s + m].copy()
        got = np.asarray(k_epsmb(t, p, tile=128))
        assert got[s], f"missed straddling occurrence at {s}"
        np.testing.assert_array_equal(got, baselines.naive_np(t, p))


def test_epsmc_apron_matches_previous_tile(rng):
    # matches that START in the previous tile (apron writes)
    t = make_text(rng, 9000, 2)  # tiny alphabet → many near-misses
    m = 20
    p = extract_pattern(rng, t, m)
    got = np.asarray(k_epsmc(t, p))
    np.testing.assert_array_equal(got, baselines.naive_np(t, p))


def test_kernel_errors(rng):
    t = make_text(rng, 100, 4)
    with pytest.raises(ValueError):
        k_epsmb(t, make_text(rng, 3, 4))
    with pytest.raises(ValueError):
        k_epsmc(t, make_text(rng, 15, 4))
    with pytest.raises(ValueError):
        k_epsma(t, np.zeros(0, dtype=np.uint8))
