"""Fused streaming megakernel (kernels/megascan): kernel-vs-engine-oracle
bit-identity for every grid shape (ntiles 1..4, partial and exact tiles),
regime mixes a/b/c, the k-mismatch 'x' groups, seam phases (prev_ov), the
spec-eligibility rules, and the StreamScanner(use_kernel=True) integration
with its one-dispatch-per-chunk contract."""

import numpy as np
import pytest

from repro.core import engine, epsm
from repro.core.stream import StreamScanner
from repro.kernels.megascan import (
    DEFAULT_TILE,
    build_mega_spec,
    megascan_count_window,
    megascan_count_window_ref,
)

from conftest import make_text

LENGTHS = (2, 5, 8, 13, 16, 24)  # covers regimes a, b (two), b, c (two)


def _plans(rng, text, lengths, k=0):
    pats = []
    for m in lengths:
        s = rng.randint(0, len(text) - m + 1)
        pats.append(text[s : s + m].copy())
        pats.append(rng.randint(0, 5, size=m).astype(np.uint8))
    return pats, engine.compile_patterns(pats, k=k)


def _check(window, plans, spec, *, k=None, prev_ov=0):
    got = np.asarray(
        megascan_count_window(
            window, plans, spec, prev_ov=prev_ov, interpret=True
        )
    )
    want = np.asarray(
        megascan_count_window_ref(window, plans, k=k, prev_ov=prev_ov)
    )
    np.testing.assert_array_equal(
        got, want, err_msg=f"n={len(window)} tile={spec.tile} ov={prev_ov}"
    )
    return got


@pytest.mark.parametrize(
    "n",
    [
        200,        # single partial tile
        1024,       # exactly one tile
        1025,       # one tile + 1 byte
        2048,       # exactly two tiles
        3000,       # three tiles, last partial
        4096,       # exactly four tiles
    ],
)
def test_kernel_matches_oracle_every_grid_shape(rng, n):
    """Interpret-mode kernel == engine oracle for every grid shape: window
    sizes hitting 1..4 tiles, both exact multiples and partial last tiles,
    over an a/b/c regime mix."""
    tile = 1024
    text = make_text(rng, n, 4)
    pats, plans = _plans(rng, text, LENGTHS)
    spec = build_mega_spec(plans, tile=tile)
    assert spec is not None and spec.tile == tile
    counts = _check(text, plans, spec)
    # sanity: extracted patterns actually hit
    for row, pid in enumerate(engine.plan_order(plans)):
        want = int(np.asarray(epsm.find(text, pats[pid])).sum())
        assert counts[row] == want, f"pattern {pid}"


@pytest.mark.parametrize("prev_ov", [0, 1, 13, 31, 100])
def test_kernel_seam_phases(rng, prev_ov):
    """The in-kernel seam gate (start + m - 1 >= prev_ov) matches the
    engine's fused end_min semantics at aligned and beta-unaligned
    overlap phases."""
    text = make_text(rng, 2500, 4)
    _, plans = _plans(rng, text, LENGTHS)
    spec = build_mega_spec(plans, tile=1024)
    assert spec is not None
    _check(text, plans, spec, prev_ov=prev_ov)


@pytest.mark.parametrize("tile", [256, 512])
def test_kernel_small_tiles(rng, tile):
    """Smaller tiles change every group's per-tile geometry (c-group block
    ownership in particular); identity must hold regardless."""
    text = make_text(rng, 1500, 4)
    _, plans = _plans(rng, text, (2, 8, 16))
    spec = build_mega_spec(plans, tile=tile)
    assert spec is not None
    for ov in (0, 7):
        _check(text, plans, spec, prev_ov=ov)


@pytest.mark.parametrize("prev_ov", [0, 13])
def test_kernel_k_mismatch_groups(rng, prev_ov):
    """k=1 routes every group through the 'x' int8-accumulator matcher
    (relaxed-LUT gated where available); identity holds with the seam gate
    folded in."""
    text = make_text(rng, 2000, 4)
    _, plans = _plans(rng, text, (2, 5, 8, 13, 16), k=1)  # m=2: no packed word
    spec = build_mega_spec(plans, k=1, tile=1024)
    assert spec is not None
    assert all(g.kind == "x" for g in spec.groups)
    assert any(g.use_lut for g in spec.groups)
    _check(text, plans, spec, k=1, prev_ov=prev_ov)


def test_spec_eligibility_rules(rng):
    """build_mega_spec returns None (pure-JAX fused fallback) for every
    documented ineligibility: pattern longer than the halo allows, EPSMc
    stride + m > tile, k beyond the int8 clamp, and empty plan sets."""
    text = make_text(rng, 4000, 4)
    _, plans_c = _plans(rng, text, (64,))
    # m=64: stride+m exceeds a 64-byte tile -> None; big tile -> eligible
    assert build_mega_spec(plans_c, tile=64) is None
    assert build_mega_spec(plans_c, tile=1024) is not None
    _, plans_b = _plans(rng, text, (8,))
    # m > tile - PACK + 1
    assert build_mega_spec(plans_b, tile=4) is None
    # k > 127 blows the int8 clamp ceiling
    assert build_mega_spec(plans_b, k=128, tile=1024) is None
    assert build_mega_spec([], tile=1024) is None
    # default tile accepts the standard mixed set
    _, plans = _plans(rng, text, LENGTHS)
    spec = build_mega_spec(plans)
    assert spec is not None and spec.tile == DEFAULT_TILE


def test_stream_scanner_use_kernel_bit_identity(rng):
    """StreamScanner(use_kernel=True) consumes kernel outputs directly:
    counts are bit-identical to the per-group reference scanner AND the
    resident engine, with exactly one dispatch per chunk."""
    text = make_text(rng, 20_000, 4)
    pats, plans = _plans(rng, text, LENGTHS)
    ref = StreamScanner(plans, 2048, fused=False)
    want = ref.count_many(text)
    sc = StreamScanner(plans, 2048, use_kernel=True)
    assert sc.spec is not None
    n_windows = sum(1 for _ in sc._windows(text))
    got = sc.count_many(text)
    assert sc.dispatch_count == n_windows  # exactly 1 dispatch per chunk
    np.testing.assert_array_equal(got, want)
    for row, pid in enumerate(sc.order):
        assert got[row] == int(np.asarray(epsm.find(text, pats[pid])).sum())


def test_stream_scanner_use_kernel_falls_back(rng):
    """When the plan set is kernel-ineligible the scanner silently keeps
    the pure-JAX fused path (spec=None) and stays exact."""
    text = make_text(rng, 8_000, 4)
    pats = [text[100:164].copy()]
    plans = engine.compile_patterns(pats, k=200)  # k > 127 blows the int8 clamp
    sc = StreamScanner(plans, 2048, k=200, use_kernel=True)
    assert sc.spec is None
    want = StreamScanner(plans, 2048, k=200, fused=False).count_many(text)
    np.testing.assert_array_equal(sc.count_many(text), want)
