"""Correctness of the pure-JAX EPSM algorithms against the scalar oracle."""

import numpy as np
import pytest

from repro.core import baselines, epsm

from conftest import extract_pattern, make_text

ALGOS = ["epsma", "epsmb", "epsmc", "auto"]
LENGTHS = [1, 2, 3, 4, 5, 7, 8, 12, 15, 16, 17, 20, 24, 31, 32]


def _min_m(algo):
    # epsmb/epsmc fall back to the lighter algorithm below their regime, so
    # every algo accepts every m; regimes are exercised by the sweep.
    return 1


@pytest.mark.parametrize("sigma", [2, 4, 20, 256])
@pytest.mark.parametrize("algo", ALGOS)
def test_matches_oracle(rng, sigma, algo):
    n = 3000
    t = make_text(rng, n, sigma)
    for m in LENGTHS:
        # extracted pattern (guaranteed occurrences) and random pattern
        for p in (extract_pattern(rng, t, m), make_text(rng, m, sigma)):
            oracle = baselines.naive_np(t, p)
            got = np.asarray(epsm.find(t, p, algo=algo))
            assert got.dtype == np.bool_
            np.testing.assert_array_equal(got, oracle, err_msg=f"m={m}")


def test_overlapping_occurrences(rng):
    # periodic pattern => overlapping matches must all be reported
    t = np.tile(np.array([1, 2], dtype=np.uint8), 50)
    for m in [2, 4, 6, 16, 20]:
        p = np.tile(np.array([1, 2], dtype=np.uint8), m // 2)
        oracle = baselines.naive_np(t, p)
        for algo in ALGOS:
            np.testing.assert_array_equal(
                np.asarray(epsm.find(t, p, algo=algo)), oracle
            )


def test_all_equal_bytes():
    t = np.zeros(257, dtype=np.uint8)
    for m in [1, 3, 5, 16, 32]:
        p = np.zeros(m, dtype=np.uint8)
        got = np.asarray(epsm.find(t, p))
        oracle = baselines.naive_np(t, p)
        np.testing.assert_array_equal(got, oracle)
        assert got.sum() == len(t) - m + 1


def test_short_text_and_edge_sizes(rng):
    for n in [0, 1, 2, 5, 16, 17]:
        t = make_text(rng, n, 4) if n else np.zeros(0, dtype=np.uint8)
        for m in [1, 2, 4, 16, 32]:
            p = make_text(rng, m, 4)
            got = np.asarray(epsm.find(t, p))
            oracle = baselines.naive_np(t, p)
            np.testing.assert_array_equal(got, oracle)


def test_match_at_boundaries(rng):
    t = make_text(rng, 1000, 4)
    for m in [2, 8, 17, 32]:
        for s in (0, len(t) - m):  # occurrence at the very start and very end
            p = t[s : s + m].copy()
            got = np.asarray(epsm.find(t, p))
            assert got[s]
            np.testing.assert_array_equal(got, baselines.naive_np(t, p))


def test_dispatcher_regimes():
    assert epsm.select_algo(1) == "epsma"
    assert epsm.select_algo(3) == "epsma"
    assert epsm.select_algo(4) == "epsmb"
    assert epsm.select_algo(15) == "epsmb"
    assert epsm.select_algo(16) == "epsmc"
    assert epsm.select_algo(64) == "epsmc"


def test_count_and_positions(rng):
    t = make_text(rng, 2000, 4)
    p = extract_pattern(rng, t, 6)
    oracle = baselines.naive_np(t, p)
    assert int(epsm.count(t, p)) == oracle.sum()
    np.testing.assert_array_equal(epsm.positions(t, p), np.nonzero(oracle)[0])


def test_string_and_bytes_inputs():
    mask = np.asarray(epsm.find("abracadabra", "abra"))
    assert list(np.nonzero(mask)[0]) == [0, 7]
    mask = np.asarray(epsm.find(b"aaaa", b"aa"))
    assert list(np.nonzero(mask)[0]) == [0, 1, 2]


def test_jit_paths(rng):
    import jax.numpy as jnp

    t = jnp.asarray(make_text(rng, 512, 4))
    p = t[17:25]
    got = np.asarray(epsm.find_jit(t, p))
    np.testing.assert_array_equal(got, baselines.naive_np(t, p))
    assert int(epsm.count_jit(t, p)) == baselines.naive_np(t, p).sum()


def test_errors():
    with pytest.raises(ValueError):
        epsm.find(b"abc", b"")
    with pytest.raises(ValueError):
        epsm.find(b"abc", b"a", algo="nope")
