"""Grep-as-a-service query plane (DESIGN.md §15, repro/serve/query_plane.py).

The acceptance properties ISSUE 10 names:
  * coalesced batches are BIT-IDENTICAL to sequential per-query dispatches,
    under concurrent asyncio load with mixed pattern lengths, mixed k, and
    result-cache hits in the stream;
  * admission control rejects deterministically at the configured depth;
  * the corpus LRU evicts by byte budget, reports evictions, and either
    404s or transparently reloads depending on the loader hook;
  * the exported service trace passes benchmarks/validate_trace.py.
"""

import asyncio
import json
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))

from benchmarks.validate_trace import validate_trace  # noqa: E402

from repro.core import engine
from repro.obs.recorder import Recorder
from repro.serve.query_plane import (
    CorpusCache,
    QueryPlane,
    QueryRejected,
    ServiceConfig,
    UnknownCorpus,
    canonical_union,
)
from repro.serve.server import GrepClient, GrepServer


def _mk_text(rng, n=20_000):
    text = rng.randint(97, 123, size=n).astype(np.uint8)
    words = [b"needle", b"xy", b"longneedlepattern_over16", b"abcd"]
    for i, w in enumerate(words * 40):
        pos = int(rng.randint(0, n - 32))
        text[pos : pos + len(w)] = np.frombuffer(w, np.uint8)
    return text.tobytes()


def _oracle_counts(text: bytes, patterns, k=0):
    """Per-query reference: its own non-canonical compile + dispatch."""
    arr = np.frombuffer(text, np.uint8)[None, :].copy()
    idx = engine.build_index(arr, np.array([len(text)], np.int32))
    plans = engine.compile_patterns(list(patterns), k=k)
    out = np.asarray(engine.count_many(idx, plans, k=k))[0]
    inv = np.argsort(engine.plan_order(plans))
    return out[inv].astype(np.int32)


# ---------------------------------------------------------------------------
# canonical union construction
# ---------------------------------------------------------------------------

def test_canonical_union_pads_groups_to_pow2():
    pats = [b"ab", b"cd", b"ef", b"abcd", b"xy", b"ab"]  # dup collapses
    union, position = canonical_union(pats)
    by_len = {}
    for p in union:
        by_len.setdefault(len(p), []).append(p)
    assert len(by_len[2]) == 4  # 3 unique -> padded to 4
    assert len(by_len[4]) == 1
    # every input pattern resolves to a union slot holding itself
    for p in set(pats):
        assert union[position[p]] == p
    # deterministic: same multiset, same union
    assert canonical_union(list(reversed(pats)))[0][:3] != ()
    u2, _ = canonical_union(pats)
    assert u2 == union


def test_canonical_plans_share_jit_signature():
    """Two same-shape canonical unions must produce identical plan aux
    data — the no-retrace property the service depends on."""
    a = engine.compile_patterns([b"aaaaaaaa", b"bbbbbbbb"], canonical=True)
    b = engine.compile_patterns([b"cccccccc"[:8], b"ddddddzz"], canonical=True)
    assert [p.tree_flatten()[1] for p in a] == [
        p.tree_flatten()[1] for p in b
    ]


# ---------------------------------------------------------------------------
# coalesced == per-query bit-identity
# ---------------------------------------------------------------------------

def test_coalesced_bit_identity_under_concurrent_load(rng):
    """Mixed pattern lengths and duplicated hot patterns across ~40
    concurrent queries: every coalesced answer equals its own standalone
    dispatch, and coalescing actually shared dispatches."""
    text = _mk_text(rng)
    pools = [
        [b"needle", b"xy", b"abcd"],
        [b"longneedlepattern_over16", b"needle"],
        [b"zz", b"qjx", b"needle", b"vwxyza"],
        [b"nomatchhere"],
    ]

    async def main():
        plane = QueryPlane(ServiceConfig(coalesce_ms=5.0, max_batch=64))
        plane.add_corpus("c", text)
        queries = [pools[i % len(pools)] for i in range(40)]
        results = await asyncio.gather(
            *[plane.query("c", q) for q in queries]
        )
        await plane.close()
        return queries, results, plane.counters

    queries, results, counters = asyncio.run(main())
    for q, r in zip(queries, results):
        expect = _oracle_counts(text, q)
        assert np.array_equal(r.counts, expect), (q, r.counts, expect)
    assert counters["dispatches"] < counters["requests"]
    assert counters["dispatched_queries"] >= 40 - counters["result_cache_hits"]


def test_coalesced_bit_identity_mixed_k(rng):
    """k=0 and k=1 queries over the same corpus coalesce into SEPARATE
    buckets (k is part of the dispatch signature) and both stay exact."""
    text = _mk_text(rng)

    async def main():
        plane = QueryPlane(ServiceConfig(coalesce_ms=5.0))
        plane.add_corpus("c", text)
        k0 = [plane.query("c", [b"needle", b"abcd"]) for _ in range(3)]
        k1 = [plane.query("c", [b"needlz"], k=1) for _ in range(3)]
        res = await asyncio.gather(*k0, *k1)
        await plane.close()
        return res

    res = asyncio.run(main())
    exp0 = _oracle_counts(text, [b"needle", b"abcd"])
    exp1 = _oracle_counts(text, [b"needlz"], k=1)
    for r in res[:3]:
        assert np.array_equal(r.counts, exp0)
    for r in res[3:]:
        assert r.k == 1 and np.array_equal(r.counts, exp1)


def test_match_mode_positions(rng):
    text = b"ab" + _mk_text(rng, 4_000) + b"needle"

    async def main():
        plane = QueryPlane(ServiceConfig(coalesce_ms=1.0))
        plane.add_corpus("c", text)
        r = await plane.query("c", [b"needle", b"ab"], mode="match")
        await plane.close()
        return r

    r = asyncio.run(main())
    raw = np.frombuffer(text, np.uint8)
    for pat, pos in zip([b"needle", b"ab"], r.positions):
        w = np.frombuffer(pat, np.uint8)
        expect = np.asarray(
            [
                i
                for i in range(len(text) - len(pat) + 1)
                if np.array_equal(raw[i : i + len(pat)], w)
            ],
            np.int64,
        )
        assert np.array_equal(pos, expect)
    assert np.array_equal(r.counts, [p.size for p in r.positions])


def test_result_cache_hits_are_bit_identical(rng):
    text = _mk_text(rng)

    async def main():
        plane = QueryPlane(ServiceConfig(coalesce_ms=0.0))
        plane.add_corpus("c", text)
        first = await plane.query("c", [b"needle", b"xy"])
        again = await plane.query("c", [b"needle", b"xy"])
        await plane.close()
        return first, again, plane.counters

    first, again, counters = asyncio.run(main())
    assert not first.cached and again.cached
    assert counters["result_cache_hits"] == 1
    assert np.array_equal(first.counts, again.counts)


def test_open_bucket_not_reused_after_corpus_replacement():
    """Replacing a corpus's content while a coalescing bucket is open must
    not let later queries join the stale bucket: the bucket key carries the
    content digest, so the parked query answers against the OLD index, the
    new query against the NEW one, and the result cache (keyed by digest)
    never stores a stale answer under the new content."""
    old_text = b"needle" * 10 + b"x" * 100
    new_text = b"x" * 160  # zero needles

    async def main():
        plane = QueryPlane(
            ServiceConfig(coalesce_ms=60_000.0, flush_on_idle=False)
        )
        plane.add_corpus("c", old_text)
        t1 = asyncio.create_task(plane.query("c", [b"needle"]))
        await asyncio.sleep(0)  # t1 parks in the open bucket
        plane.add_corpus("c", new_text)  # content replaced mid-bucket
        t2 = asyncio.create_task(plane.query("c", [b"needle"]))
        await asyncio.sleep(0)
        assert len(plane._batches) == 2  # digest split the buckets
        await plane.flush()
        r1, r2 = await t1, await t2
        r3 = await plane.query("c", [b"needle"])  # cache, new digest
        await plane.close()
        return r1, r2, r3

    r1, r2, r3 = asyncio.run(main())
    assert r1.counts[0] == 10   # parked query: old content's answer
    assert r2.counts[0] == 0    # joining query: new content's answer
    assert r3.cached and r3.counts[0] == 0


# ---------------------------------------------------------------------------
# admission control / backpressure
# ---------------------------------------------------------------------------

def test_admission_queue_rejects_at_depth(rng):
    """With an effectively-infinite coalescing window, admitted queries
    park in the open batch: query max_pending+1 must raise QueryRejected,
    and a flush() drains the parked ones successfully."""
    text = _mk_text(rng, 4_000)

    async def main():
        plane = QueryPlane(
            ServiceConfig(coalesce_ms=60_000.0, max_batch=10_000,
                          max_pending=5, result_cache_entries=0,
                          flush_on_idle=False)
        )
        plane.add_corpus("c", text)
        parked = [
            asyncio.create_task(plane.query("c", [b"needle"]))
            for _ in range(5)
        ]
        await asyncio.sleep(0)  # let tasks enter the batch
        assert plane.stats()["pending"] == 5
        with pytest.raises(QueryRejected):
            await plane.query("c", [b"xy"])
        assert plane.counters["rejected"] == 1
        await plane.flush()
        results = await asyncio.gather(*parked)
        await plane.close()
        return results

    results = asyncio.run(main())
    expect = _oracle_counts(text, [b"needle"])
    assert all(np.array_equal(r.counts, expect) for r in results)
    # all five parked queries shared ONE dispatch
    assert all(r.batched == 5 for r in results)


def test_flush_on_idle_dispatch_clocked_batching(rng):
    """Dispatch-clocked coalescing: an idle dispatcher takes the first
    query immediately (no window latency), and everything arriving while
    it runs coalesces into exactly one follow-up dispatch — even with an
    effectively-infinite coalesce_ms cap."""
    text = _mk_text(rng, 4_000)

    async def main():
        plane = QueryPlane(
            ServiceConfig(coalesce_ms=60_000.0, max_batch=10_000,
                          result_cache_entries=0)
        )
        plane.add_corpus("c", text)
        results = await asyncio.gather(
            *[plane.query("c", [b"needle"]) for _ in range(10)]
        )
        await plane.close()
        return results, plane.counters

    results, counters = asyncio.run(main())
    assert counters["dispatches"] == 2
    assert sorted(r.batched for r in results) == [1] + [9] * 9
    expect = _oracle_counts(text, [b"needle"])
    assert all(np.array_equal(r.counts, expect) for r in results)


def test_coalesce_zero_arms_no_timer(rng):
    """coalesce_ms=0 under flush_on_idle means NO timer at all (the doc'd
    'disables time-based coalescing') — previously a call_later(0) re-armed
    itself every loop iteration for the whole duration of each dispatch.
    Liveness comes from the idle-flush and the dispatch-completion flush."""
    text = _mk_text(rng, 4_000)

    async def main():
        plane = QueryPlane(
            ServiceConfig(coalesce_ms=0.0, result_cache_entries=0)
        )
        plane.add_corpus("c", text)
        plane._inflight = 1  # park arrivals as if a dispatch were running
        task = asyncio.create_task(plane.query("c", [b"needle"]))
        await asyncio.sleep(0)
        (batch,) = plane._batches.values()
        assert batch.timer is None
        plane._inflight = 0
        await plane.flush()
        r = await task
        await plane.close()
        return r

    r = asyncio.run(main())
    assert np.array_equal(r.counts, _oracle_counts(text, [b"needle"]))


def test_rejection_does_not_leak_pending(rng):
    text = _mk_text(rng, 4_000)

    async def main():
        plane = QueryPlane(
            ServiceConfig(coalesce_ms=0.0, max_pending=2,
                          result_cache_entries=0)
        )
        plane.add_corpus("c", text)
        for _ in range(4):  # sequential: never exceeds depth 1
            await plane.query("c", [b"xy"])
        assert plane.stats()["pending"] == 0
        await plane.close()

    asyncio.run(main())


# ---------------------------------------------------------------------------
# corpus cache eviction
# ---------------------------------------------------------------------------

def _budget_for(texts):
    """Byte budget that fits exactly ONE of the (equal-sized) corpora."""
    cache = CorpusCache(1 << 62)
    e = cache.put("probe", texts[0])
    return e.nbytes + 1


def test_corpus_lru_eviction_and_404(rng):
    texts = [_mk_text(rng, 8_000) for _ in range(3)]

    async def main():
        plane = QueryPlane(
            ServiceConfig(coalesce_ms=0.0,
                          corpus_budget_bytes=_budget_for(texts))
        )
        rec = Recorder(enabled=True, fence=False)
        plane.rec = plane.corpora.rec = rec
        for i, t in enumerate(texts):
            plane.add_corpus(f"c{i}", t)
        # only the most recent survives the byte budget
        assert plane.corpora.ids() == ("c2",)
        evicts = rec.events_named("corpus_evict")
        assert [e["corpus"] for e in evicts] == ["c0", "c1"]
        r = await plane.query("c2", [b"needle"])
        assert np.array_equal(r.counts, _oracle_counts(texts[2], [b"needle"]))
        with pytest.raises(UnknownCorpus):
            await plane.query("c0", [b"needle"])
        await plane.close()

    asyncio.run(main())


def test_corpus_eviction_transparent_reload(rng):
    texts = {f"c{i}": _mk_text(rng, 8_000) for i in range(2)}

    async def main():
        plane = QueryPlane(
            ServiceConfig(coalesce_ms=0.0,
                          corpus_budget_bytes=_budget_for(list(texts.values()))),
            loader=lambda cid: texts[cid],
        )
        plane.add_corpus("c0", texts["c0"])
        plane.add_corpus("c1", texts["c1"])  # evicts c0
        assert plane.corpora.ids() == ("c1",)
        r = await plane.query("c0", [b"needle"])  # transparently reloads
        await plane.close()
        return r, plane.counters

    r, counters = asyncio.run(main())
    assert counters["corpus_reloads"] == 1
    assert np.array_equal(r.counts, _oracle_counts(texts["c0"], [b"needle"]))


def test_concurrent_reloads_share_one_loader_call(rng):
    """A reload runs loader + index build on the executor (the event loop
    stays responsive) and concurrent misses for the same corpus share ONE
    in-flight reload instead of building the index N times."""
    texts = {f"c{i}": _mk_text(rng, 8_000) for i in range(2)}
    calls = []

    def loader(cid):
        calls.append(cid)
        return texts[cid]

    async def main():
        plane = QueryPlane(
            ServiceConfig(coalesce_ms=0.0,
                          corpus_budget_bytes=_budget_for(list(texts.values()))),
            loader=loader,
        )
        plane.add_corpus("c0", texts["c0"])
        plane.add_corpus("c1", texts["c1"])  # evicts c0
        rs = await asyncio.gather(
            *[plane.query("c0", [b"needle"]) for _ in range(5)]
        )
        await plane.close()
        return rs, plane.counters

    rs, counters = asyncio.run(main())
    assert calls == ["c0"]
    assert counters["corpus_reloads"] == 1
    expect = _oracle_counts(texts["c0"], [b"needle"])
    assert all(np.array_equal(r.counts, expect) for r in rs)


def test_corpus_get_refreshes_lru(rng):
    texts = [_mk_text(rng, 8_000) for _ in range(2)]
    cache = CorpusCache(1 << 62)
    cache.put("a", texts[0])
    cache.put("b", texts[1])
    cache.get("a")  # refresh
    assert cache.ids() == ("b", "a")


# ---------------------------------------------------------------------------
# server round trip + trace hygiene
# ---------------------------------------------------------------------------

def test_server_roundtrip_matches_engine(rng):
    text = _mk_text(rng)

    async def main():
        plane = QueryPlane(ServiceConfig(coalesce_ms=1.0))
        async with GrepServer(plane) as (host, port):
            clients = [await GrepClient.connect(host, port) for _ in range(3)]
            await clients[0].add_corpus("c", text)
            outs = await asyncio.gather(
                *[c.query("c", [b"needle", b"xy"]) for c in clients]
            )
            missing = await clients[0].query("nope", [b"x"])
            stats = await clients[0].stats()
            for c in clients:
                await c.close()
        return outs, missing, stats

    outs, missing, stats = asyncio.run(main())
    expect = [int(c) for c in _oracle_counts(text, [b"needle", b"xy"])]
    for o in outs:
        assert o["ok"] and o["counts"] == expect
    assert missing["status"] == 404 and missing["error"] == "unknown_corpus"
    assert stats["stats"]["requests"] >= 3


def test_server_dispatch_failure_answers_500_and_keeps_connection(rng):
    """An unexpected error out of the plane (e.g. the RuntimeError a failed
    dispatch fans out to its futures) must come back as a 500 response, not
    tear down the connection with no reply."""
    text = _mk_text(rng, 4_000)

    async def main():
        plane = QueryPlane(ServiceConfig(coalesce_ms=1.0))
        plane.add_corpus("c", text)

        async def boom(*args, **kw):
            raise RuntimeError("dispatch failed: injected")

        plane.query = boom
        async with GrepServer(plane) as (host, port):
            client = await GrepClient.connect(host, port)
            resp = await client.query("c", [b"needle"])
            pong = await client.ping()  # connection survived the failure
            await client.close()
        return resp, pong

    resp, pong = asyncio.run(main())
    assert not resp["ok"] and resp["status"] == 500
    assert "injected" in resp["detail"]
    assert pong["ok"]


def test_service_trace_passes_validator(rng, tmp_path):
    text = _mk_text(rng)

    async def main():
        rec = Recorder(enabled=True, fence=True)
        plane = QueryPlane(
            ServiceConfig(coalesce_ms=2.0), recorder=rec
        )
        plane.add_corpus("c", text)
        await asyncio.gather(
            *[plane.query("c", [b"needle", b"xy"]) for _ in range(8)],
            plane.query("c", [b"abcd"], mode="match"),
        )
        await plane.close()
        return rec

    rec = asyncio.run(main())
    out = tmp_path / "service_trace.json"
    rec.export_trace(out)
    trace = json.loads(out.read_text())
    assert validate_trace(trace) == len(trace["traceEvents"])
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "X"}
    assert {"service_batch", "plan_union", "engine_dispatch"} <= names
    # latency SLO histograms are populated
    plane_hist = rec.metrics.summary()["histograms"]
    assert plane_hist["service.request_ms"]["count"] == 9
    assert "p99" in plane_hist["service.request_ms"]
