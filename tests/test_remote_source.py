"""RemoteRangeReader: the reference (start, stop) range source — parts,
bounded prefetch, per-part timeout, classified retry with jittered
exponential backoff — and FakeObjectStore, its in-process test double.
DESIGN.md §12 is the contract."""

import threading
import time

import numpy as np
import pytest

from conftest import make_text

from repro.core import engine
from repro.core.remote_source import (
    FakeObjectStore,
    RangeReadTimeout,
    RemoteRangeReader,
)
from repro.core.shard_stream import ShardedStreamScanner, source_total_bytes
from repro.core.stream import StreamScanner
from repro.dist.fault_injection import FaultPlan, InjectedReadError
from repro.dist.fault_tolerance import BackoffPolicy, FatalScanError


def _drain(it):
    return np.concatenate([np.asarray(c) for c in it] or [np.zeros(0, np.uint8)])


def test_reader_delivers_exact_bytes_in_parts(rng):
    data = make_text(rng, 10_000, 7)
    store = FakeObjectStore(data)
    reader = store.reader(part_bytes=1024, prefetch=3)
    got = _drain(reader(100, 7300))
    np.testing.assert_array_equal(got, data[100:7300])
    # ceil(7200 / 1024) parts, one GET each, no retries
    assert reader.stats.parts == 8
    assert reader.stats.gets == 8
    assert reader.stats.bytes == 7200
    assert reader.stats.retries == 0
    # total_bytes picked up from the store: range partitioning just works
    assert source_total_bytes(reader) == len(data)
    # empty range is legal and empty
    assert len(_drain(reader(50, 50))) == 0


def test_reader_is_reopenable_and_bad_ranges_raise(rng):
    data = make_text(rng, 4_000, 5)
    reader = FakeObjectStore(data).reader(part_bytes=512)
    a = _drain(reader(0, 2000))
    b = _drain(reader(0, 2000))  # fresh iterator, same bytes
    np.testing.assert_array_equal(a, b)
    with pytest.raises(ValueError):
        reader(100, 5000)  # past the end
    with pytest.raises(ValueError):
        reader(-1, 10)


def test_transient_faults_retry_with_recorded_backoff(rng):
    """Injected 5xx-style errors heal after attempts_per_fault failures; the
    reader retries them with the exact (seeded) backoff schedule."""
    data = make_text(rng, 8_192, 4)
    plan = FaultPlan(3, read_error_rate=0.3, attempts_per_fault=1)
    store = FakeObjectStore(data, plan=plan)
    delays = []
    reader = store.reader(
        part_bytes=1024,
        retries=3,
        backoff=BackoffPolicy(base_s=0.01, jitter=0.5, seed=7),
        sleep=delays.append,
    )
    got = _drain(reader(0, len(data)))
    np.testing.assert_array_equal(got, data)
    n_faults = len([e for e in plan.events if e.action == "read_error"])
    assert n_faults > 0
    assert reader.stats.retries == n_faults == len(delays)
    # same schedule the policy would produce, verbatim
    ref = BackoffPolicy(base_s=0.01, jitter=0.5, seed=7)
    assert delays == pytest.approx([ref.delay_s(0) for _ in delays])


def test_short_response_is_retryable_never_delivered(rng):
    """A part answering the wrong number of bytes is retried, and the
    consumer never sees the short payload."""
    data = make_text(rng, 4_096, 4)
    plan = FaultPlan(11, truncate_rate=0.4, attempts_per_fault=1)
    store = FakeObjectStore(data, plan=plan)
    reader = store.reader(part_bytes=512, retries=2)
    got = _drain(reader(0, len(data)))
    np.testing.assert_array_equal(got, data)
    n_trunc = len([e for e in plan.events if e.action == "truncate"])
    assert n_trunc > 0 and reader.stats.retries >= n_trunc


def test_permanent_fault_exhausts_retries(rng):
    data = make_text(rng, 2_048, 4)
    plan = FaultPlan(5, read_error_rate=1.0, attempts_per_fault=None)
    reader = FakeObjectStore(data, plan=plan).reader(
        part_bytes=512, retries=2, sleep=lambda s: None
    )
    with pytest.raises(InjectedReadError):
        _drain(reader(0, 1024))
    assert reader.stats.retries == 2  # budget spent, then raised


def test_fatal_errors_skip_the_retry_budget():
    calls = []

    def fetch(s, e):
        calls.append((s, e))
        raise FatalScanError("object gone")

    fetch.total_bytes = 4096
    reader = RemoteRangeReader(fetch, retries=5, part_bytes=1024)
    with pytest.raises(FatalScanError):
        _drain(reader(0, 1024))
    assert len(calls) == 1  # classified non-retryable: one attempt, no backoff
    assert reader.stats.retries == 0


def test_timeout_abandons_the_attempt_and_retries():
    """A part slower than timeout_s counts as a timeout and retries; the
    abandoned call finishes on its worker thread without corrupting later
    attempts."""
    data = bytes(range(256)) * 16
    slow_once = {"left": 1}
    lock = threading.Lock()

    def fetch(s, e):
        with lock:
            slow = slow_once["left"] > 0
            slow_once["left"] -= 1
        if slow:
            time.sleep(0.25)
        return data[s:e]

    fetch.total_bytes = len(data)
    reader = RemoteRangeReader(
        fetch, part_bytes=1024, prefetch=1, timeout_s=0.05,
        retries=2, sleep=lambda s: None,
    )
    got = _drain(reader(0, len(data)))
    np.testing.assert_array_equal(got, np.frombuffer(data, np.uint8))
    assert reader.stats.timeouts == 1
    assert reader.stats.retries == 1


def test_timeout_exhaustion_raises_range_read_timeout():
    def fetch(s, e):
        time.sleep(0.2)
        return b"x" * (e - s)

    fetch.total_bytes = 1024
    reader = RemoteRangeReader(
        fetch, part_bytes=1024, timeout_s=0.02, retries=1, sleep=lambda s: None
    )
    with pytest.raises(RangeReadTimeout):
        _drain(reader(0, 1024))
    assert reader.stats.timeouts == 2


def test_prefetch_is_bounded(rng):
    """No more than `prefetch` parts run ahead of the consumer: after the
    first piece arrives, at most 1 + prefetch GETs have been issued."""
    data = make_text(rng, 8_192, 4)
    store = FakeObjectStore(data)
    reader = store.reader(part_bytes=1024, prefetch=2)
    it = reader(0, len(data))
    next(it)
    # parts are submitted before blocking on the head: bound is prefetch
    # in flight at once (the delivered part freed one slot)
    assert store.gets <= 3
    _drain(it)
    assert store.gets == 8


def test_sharded_scan_over_remote_reader_is_exact(rng):
    """End to end: ShardedStreamScanner over the remote protocol, with
    transient faults in the store, equals the local scan bit-for-bit."""
    text = make_text(rng, 60_000, 4)
    pats = [text[37:45].copy(), text[1003:1007].copy(), b"zz"]
    plans = engine.compile_patterns(pats)
    want = StreamScanner(plans, 4096).count_many(text)
    want_pos = StreamScanner(plans, 4096).positions_many(text)

    plan = FaultPlan(2, read_error_rate=0.1, truncate_rate=0.1, attempts_per_fault=1)
    store = FakeObjectStore(text, plan=plan)
    reader = store.reader(part_bytes=4096, retries=3, sleep=lambda s: None)
    sc = ShardedStreamScanner(plans, 4, 4096, max_retries=2)
    np.testing.assert_array_equal(sc.count_many(reader), want)
    got_pos = ShardedStreamScanner(plans, 4, 4096, max_retries=2).positions_many(
        store.reader(part_bytes=4096, retries=3, sleep=lambda s: None)
    )
    for a, b in zip(got_pos, want_pos):
        np.testing.assert_array_equal(a, b)
