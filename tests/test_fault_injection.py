"""The elastic-fabric acceptance properties (DESIGN.md §12):

  * FaultPlan is deterministic and order-independent — the same seed fires
    the same faults at the same sites, in any execution order;
  * every seeded fault schedule x shard count produces counts/positions
    BIT-IDENTICAL to the clean single-host StreamScanner run (recovery is
    exact, stealing repartitions without changing the answer);
  * exhausted retries under on_exhausted="partial" report the exact
    missing byte ranges, and the returned counts/positions are exact over
    the covered complement.

Extend the sweep with FAULT_SEEDS=0,1,2,... (the CI chaos job does)."""

import os

import numpy as np
import pytest

from conftest import make_text

from repro.core import engine
from repro.core.shard_stream import (
    PartialScanResult,
    ShardedStreamScanner,
)
from repro.core.stream import StreamScanner
from repro.dist.fault_injection import (
    FaultPlan,
    FaultyRangeSource,
    InjectedReadError,
)
from repro.dist.fault_tolerance import BackoffPolicy, InjectedFault

FAULT_SEEDS = [int(s) for s in os.environ.get("FAULT_SEEDS", "0,1,2").split(",")]
SHARD_COUNTS = [1, 2, 4, 8]


def _corpus(rng, n=120_000):
    text = make_text(rng, n, 4)
    pats = [
        text[501:509].copy(),             # m=8, present
        text[777:779].copy(),             # m=2, frequent
        text[n // 2 : n // 2 + 32].copy(),  # m=32, verify path
        b"zzzz",                          # absent
    ]
    return text, engine.compile_patterns(pats)


# -- plan determinism ------------------------------------------------------


def test_fault_plan_is_deterministic_and_order_independent():
    kw = dict(
        read_error_rate=0.3, truncate_rate=0.3, crash_rate=0.3,
        latency_rate=0.3, latency_s=0.0,
    )
    keys = [("read", (s, i)) for s in (0, 64, 4096) for i in range(50)]
    a, b = FaultPlan(9, **kw), FaultPlan(9, **kw)

    def probe(plan, order):
        out = {}
        for kind, key in order:
            try:
                plan.check(kind, key)
                out[(kind, key)] = "ok"
            except InjectedFault:
                out[(kind, key)] = "crash"
            except InjectedReadError:
                out[(kind, key)] = "read_error"
        return out

    assert probe(a, keys) == probe(b, list(reversed(keys)))
    # a different seed gives a different schedule
    c = probe(FaultPlan(10, **kw), keys)
    assert c != probe(FaultPlan(11, **kw), keys)


def test_faults_are_transient_then_heal():
    plan = FaultPlan(1, read_error_rate=1.0, attempts_per_fault=2)
    for _ in range(2):
        with pytest.raises(InjectedReadError):
            plan.check("read", (0, 0))
    plan.check("read", (0, 0))  # healed on attempt 3
    # permanent plans never heal
    perm = FaultPlan(1, read_error_rate=1.0, attempts_per_fault=None)
    for _ in range(5):
        with pytest.raises(InjectedReadError):
            perm.check("read", (0, 0))


def test_truncate_is_deterministic_and_short():
    plan = FaultPlan(4, truncate_rate=1.0, attempts_per_fault=None)
    a = plan.truncate("read", (0, 3), 1000)
    b = FaultPlan(4, truncate_rate=1.0, attempts_per_fault=None).truncate(
        "read", (0, 3), 1000
    )
    assert a == b and 0 <= a < 1000


# -- the acceptance property: seed x shard count, bit-identical ------------


@pytest.mark.parametrize("seed", FAULT_SEEDS)
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_faulted_sharded_scan_equals_clean_oracle(rng, seed, n_shards):
    """Transient read errors, truncations, latency spikes, and shard
    crashes — recovered through retry — leave counts AND positions
    bit-identical to the clean single-host run."""
    text, plans = _corpus(rng)
    clean = StreamScanner(plans, 4096)
    want_counts = clean.count_many(text)
    want_pos = clean.positions_many(text)

    plan = FaultPlan(
        seed, read_error_rate=0.08, truncate_rate=0.08, crash_rate=0.12,
        latency_rate=0.05, latency_s=0.0, attempts_per_fault=1,
    )
    src = FaultyRangeSource(text, plan, piece_bytes=8192)
    sc = ShardedStreamScanner(
        plans, n_shards, 4096, max_retries=16, fault_plan=plan,
        backoff=BackoffPolicy(base_s=0.0, jitter=0.0),
    )
    np.testing.assert_array_equal(sc.count_many(src), want_counts)
    got_pos = ShardedStreamScanner(
        plans, n_shards, 4096, max_retries=16, fault_plan=plan,
    ).positions_many(FaultyRangeSource(text, plan, piece_bytes=8192))
    for a, b in zip(got_pos, want_pos):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("seed", FAULT_SEEDS)
@pytest.mark.parametrize("n_shards", [2, 4, 8])
def test_faulted_stealing_scan_equals_clean_oracle(rng, seed, n_shards):
    """The work-stealing path under the same fault schedules: sheds and
    steals repartition the stream at beta-aligned seams, so the merged
    result is still bit-identical to the clean oracle."""
    text, plans = _corpus(rng)
    clean = StreamScanner(plans, 4096)
    want_counts = clean.count_many(text)
    want_pos = clean.positions_many(text)

    def make(fp):
        return ShardedStreamScanner(
            plans, n_shards, 4096, max_retries=16, fault_plan=fp,
            steal=True, steal_workers=3, min_steal_bytes=1024,
            backoff=BackoffPolicy(base_s=0.0, jitter=0.0),
        )

    plan = FaultPlan(
        seed, read_error_rate=0.08, truncate_rate=0.08, crash_rate=0.12,
        latency_rate=0.1, latency_s=0.002, attempts_per_fault=1,
    )
    sc = make(plan)
    np.testing.assert_array_equal(
        sc.count_many(FaultyRangeSource(text, plan, piece_bytes=8192)),
        want_counts,
    )
    plan2 = FaultPlan(
        seed, read_error_rate=0.08, truncate_rate=0.08, crash_rate=0.12,
        latency_rate=0.1, latency_s=0.002, attempts_per_fault=1,
    )
    got_pos = make(plan2).positions_many(
        FaultyRangeSource(text, plan2, piece_bytes=8192)
    )
    for a, b in zip(got_pos, want_pos):
        np.testing.assert_array_equal(a, b)


def test_forced_steal_is_bit_identical_and_observable(rng):
    """Drive sheds deterministically (tiny min_steal_bytes + a straggling
    source) and check the steal log plus exactness."""
    text, plans = _corpus(rng, n=80_000)
    want = StreamScanner(plans, 2048).count_many(text)

    plan = FaultPlan(
        0, latency_rate=0.25, latency_s=0.004, attempts_per_fault=None
    )
    src = FaultyRangeSource(text, plan, piece_bytes=2048)
    sc = ShardedStreamScanner(
        plans, 2, 2048, steal=True, steal_workers=4, min_steal_bytes=512,
        max_retries=2,
    )
    np.testing.assert_array_equal(sc.count_many(src), want)
    # the latency spikes make steals overwhelmingly likely, but exactness
    # above is the real assertion; the log shape is checked when present
    for ev in sc.steal_events:
        assert ev.reason in ("idle", "straggler")
        assert ev.stop > ev.start
        assert ev.start % 8 == 0  # beta-aligned split


# -- graceful degradation --------------------------------------------------


@pytest.mark.parametrize("seed", FAULT_SEEDS)
def test_partial_result_reports_exact_missing_ranges(rng, seed):
    """Permanent shard crashes + on_exhausted='partial': the scan returns
    instead of raising, missing == exactly the dead shards' byte ranges,
    and counts equal a prefix-injected oracle over each covered range."""
    text, plans = _corpus(rng, n=64_000)
    n_shards = 8
    plan = FaultPlan(seed, crash_rate=0.4, attempts_per_fault=None)
    sc = ShardedStreamScanner(
        plans, n_shards, 2048, max_retries=1, fault_plan=plan,
        on_exhausted="partial",
    )
    spec = sc.shard_spec(len(text))
    res = sc.count_many(text)
    assert isinstance(res, PartialScanResult)

    dead = {
        i for i in range(n_shards)
        if plan._u("crash", "shard", i) < plan.crash_rate
    }
    from repro.dist.sharding import complement_ranges, merge_ranges

    assert res.missing == merge_ranges(spec.ranges[i] for i in dead)
    assert res.covered == complement_ranges(res.missing, len(text))
    assert res.complete == (not dead)
    assert res.covered_bytes + sum(e - s for s, e in res.missing) == len(text)

    # counts are exact over covered: occurrences whose END byte is covered
    acc = np.zeros(len(plans), np.int64)
    oracle = StreamScanner(plans, 2048)
    for s, e in res.covered:
        pre = text[max(0, s - oracle.overlap):s] if s else None
        acc = acc + oracle.count_many(iter([text[s:e]]), prefix=pre, start=s)
    np.testing.assert_array_equal(res.counts, acc.astype(res.counts.dtype))

    # positions agree with the same oracle
    plan2 = FaultPlan(seed, crash_rate=0.4, attempts_per_fault=None)
    res_pos = ShardedStreamScanner(
        plans, n_shards, 2048, max_retries=1, fault_plan=plan2,
        on_exhausted="partial",
    ).positions_many(text)
    rows = [[] for _ in plans]
    for s, e in res.covered:
        pre = text[max(0, s - oracle.overlap):s] if s else None
        got = StreamScanner(plans, 2048).positions_many(
            iter([text[s:e]]), prefix=pre, start=s
        )
        for p_i, r in enumerate(got):
            rows[p_i].append(r)
    for p_i in range(len(plans)):
        np.testing.assert_array_equal(
            res_pos.positions[p_i],
            np.concatenate(rows[p_i]) if rows[p_i] else np.zeros(0, np.int64),
        )


def test_partial_mode_with_no_faults_is_complete(rng):
    text, plans = _corpus(rng, n=20_000)
    want = StreamScanner(plans, 2048).count_many(text)
    res = ShardedStreamScanner(
        plans, 4, 2048, on_exhausted="partial"
    ).count_many(text)
    assert isinstance(res, PartialScanResult)
    assert res.complete and res.missing == ()
    assert res.covered == ((0, len(text)),)
    assert res.coverage_fraction() == 1.0
    np.testing.assert_array_equal(res.counts, want)


def test_partial_mode_steal_path_reports_missing(rng):
    """Exhaustion in the stealing path: missing ranges are beta-aligned
    subranges and counts stay exact over the covered complement."""
    text, plans = _corpus(rng, n=64_000)
    plan = FaultPlan(1, crash_rate=0.5, attempts_per_fault=None)
    sc = ShardedStreamScanner(
        plans, 8, 2048, max_retries=1, fault_plan=plan,
        on_exhausted="partial", steal=True, steal_workers=3,
        min_steal_bytes=512,
    )
    res = sc.count_many(text)
    assert isinstance(res, PartialScanResult)
    assert not res.complete  # crash_rate 0.5 over 8 shards: some must die
    acc = np.zeros(len(plans), np.int64)
    oracle = StreamScanner(plans, 2048)
    for s, e in res.covered:
        assert s % 8 == 0  # covered/missing seams stay beta-aligned
        pre = text[max(0, s - oracle.overlap):s] if s else None
        acc = acc + oracle.count_many(iter([text[s:e]]), prefix=pre, start=s)
    np.testing.assert_array_equal(res.counts, acc.astype(res.counts.dtype))


def test_on_exhausted_validates():
    text = b"x" * 100
    plans = engine.compile_patterns([b"xx"])
    with pytest.raises(ValueError):
        ShardedStreamScanner(plans, 2, on_exhausted="ignore")
