"""Elastic restart: a checkpoint written under one device layout restores
onto a DIFFERENT (8 fake device) mesh with re-sharding — the down/up-scale
path after losing or gaining nodes."""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.train import checkpoint as ckpt


@pytest.mark.slow
def test_elastic_rescale_subprocess(tmp_path):
    # phase 1 (this process, 1 device): train-ish state, save
    tree = {
        "w": jnp.arange(64.0 * 16).reshape(64, 16),
        "opt": {"m": jnp.ones((64, 16)), "step": jnp.int32(7)},
    }
    ckpt.save(tree, tmp_path, step=7)

    # phase 2 (subprocess, 8 devices): restore sharded over a (4,2) mesh
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt
from repro.dist.compat import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
tree = {{
    "w": jnp.zeros((64, 16)),
    "opt": {{"m": jnp.zeros((64, 16)), "step": jnp.int32(0)}},
}}
sh = {{
    "w": NamedSharding(mesh, P("data", "model")),
    "opt": {{"m": NamedSharding(mesh, P("data", None)),
             "step": NamedSharding(mesh, P())}},
}}
restored, step = ckpt.restore(tree, {str(tmp_path)!r}, shardings=sh)
assert step == 7
assert restored["w"].sharding == sh["w"]
assert len(restored["w"].sharding.device_set) == 8
np.testing.assert_array_equal(
    np.asarray(restored["w"]), np.arange(64.0 * 16).reshape(64, 16))
assert int(restored["opt"]["step"]) == 7
print("ELASTIC_OK")
"""
    res = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd="/root/repo",
    )
    assert "ELASTIC_OK" in res.stdout, res.stdout + res.stderr
