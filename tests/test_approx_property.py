"""Hypothesis property tests for the k-mismatch subsystem: count_many under
any budget vs a naive Python reference, over random alphabets {2, 4, 256}
and pattern lengths 2..16 (self-skipping without hypothesis, same pattern as
tests/test_property.py)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.approx import kmismatch_naive  # noqa: E402
from repro.core import engine  # noqa: E402

SETTINGS = dict(max_examples=25, deadline=None)

sigma_st = st.sampled_from([2, 4, 256])


@given(
    sigma=sigma_st,
    n=st.integers(0, 400),
    m=st.integers(2, 16),
    k=st.integers(0, 2),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_count_many_equals_naive(sigma, n, m, k, seed):
    rng = np.random.RandomState(seed)
    t = rng.randint(0, sigma, size=n).astype(np.uint8)
    p = rng.randint(0, sigma, size=m).astype(np.uint8)
    plans = engine.compile_patterns([p], k=k)
    idx = engine.build_index(t)
    got = int(np.asarray(engine.count_many_jit(idx, plans, k=k))[0, 0])
    assert got == kmismatch_naive(t, p, k).sum()


@given(
    sigma=sigma_st,
    m=st.integers(2, 16),
    k=st.integers(0, 2),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_extracted_window_with_k_typos_found(sigma, m, k, seed):
    """Completeness: corrupt an extracted window at exactly k positions —
    the budget-k scan must still report that start position."""
    rng = np.random.RandomState(seed)
    t = rng.randint(0, sigma, size=200).astype(np.uint8)
    s = rng.randint(0, len(t) - m + 1)
    p = t[s : s + m].copy()
    for j in rng.choice(m, size=k, replace=False):
        t[s + j] = rng.randint(0, 256)
    plans = engine.compile_patterns([p], k=k)
    mask = np.asarray(
        engine.match_many_jit(engine.build_index(t), plans, k=k)
    )[0, 0]
    assert mask[s]
    # soundness: every reported position really is within distance k
    for i in np.nonzero(mask)[0]:
        assert np.count_nonzero(t[i : i + m] != p) <= k


@given(
    sigma=sigma_st,
    m=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_budget_monotone(sigma, m, seed):
    """occ_k(t, p) is nondecreasing in k, and occ_0 equals the exact path."""
    rng = np.random.RandomState(seed)
    t = rng.randint(0, sigma, size=300).astype(np.uint8)
    p = rng.randint(0, sigma, size=m).astype(np.uint8)
    idx = engine.build_index(t)
    prev = None
    for k in (0, 1, 2, 3):
        plans = engine.compile_patterns([p], k=min(k, 2))
        c = int(np.asarray(engine.count_many_jit(idx, plans, k=k))[0, 0])
        if prev is not None:
            assert c >= prev
        prev = c
