"""Dry-run machinery integration: lower+compile representative cells of all
three families (and both LM sharding strategies) on a small fake-device mesh
in a subprocess (device count locks at first jax init)."""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from pathlib import Path
from repro.launch.dryrun import run_cell
from repro.dist.compat import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
out = Path("/tmp/dryrun_cells_test")
cells = [
    ("smollm-135m", "train_4k", "default"),
    ("smollm-135m", "train_4k", "zero_dp"),
    ("smollm-135m", "decode_32k", "default"),
    ("gatedgcn", "molecule", "default"),
    ("gatedgcn", "full_graph_sm", "nodes_sharded+bf16"),
    ("din", "train_batch", "default"),
    ("dcn-v2", "retrieval_cand", "default"),
]
for arch, shape, strat in cells:
    rec = run_cell(arch, shape, False, out, mesh=mesh, strategy=strat)
    assert rec["hlo_flops_per_chip"] > 0, (arch, shape)
    assert rec["roofline"]["bottleneck"] in ("compute", "memory", "collective")
    assert rec["model_flops_global"] > 0
print("DRYRUN_CELLS_OK")
"""


@pytest.mark.slow
def test_dryrun_cells_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd="/root/repo",
    )
    assert "DRYRUN_CELLS_OK" in res.stdout, res.stdout[-2000:] + res.stderr[-3000:]
