"""Hypothesis property tests on the system's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import baselines, epsm
from repro.core.multipattern import PatternSet, find_multi

SETTINGS = dict(max_examples=30, deadline=None)

bytes_text = st.binary(min_size=0, max_size=600)
small_alphabet_text = st.lists(
    st.integers(0, 3), min_size=0, max_size=600
).map(lambda xs: np.array(xs, dtype=np.uint8))


@given(t=small_alphabet_text, m=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_find_equals_oracle_random(t, m, seed):
    rng = np.random.RandomState(seed)
    p = rng.randint(0, 4, size=m).astype(np.uint8)
    got = np.asarray(epsm.find(t, p))
    np.testing.assert_array_equal(got, baselines.naive_np(t, p))


@given(t=small_alphabet_text, m=st.integers(1, 40), start=st.integers(0, 10**6))
@settings(**SETTINGS)
def test_extracted_pattern_always_found(t, m, start):
    if len(t) < m:
        return
    s = start % (len(t) - m + 1)
    p = t[s : s + m].copy()
    mask = np.asarray(epsm.find(t, p))
    assert mask[s], "extracted occurrence must be reported"
    # soundness: every reported position is a true occurrence
    for i in np.nonzero(mask)[0]:
        assert np.array_equal(t[i : i + m], p)


@given(
    a=small_alphabet_text,
    b=small_alphabet_text,
    m=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_concat_superadditive_counts(a, b, m, seed):
    """occ(a ++ b) >= occ(a) + occ(b): concatenation can only add matches."""
    rng = np.random.RandomState(seed)
    p = rng.randint(0, 4, size=m).astype(np.uint8)
    ca = int(epsm.count(a, p)) if len(a) else 0
    cb = int(epsm.count(b, p)) if len(b) else 0
    cab = int(epsm.count(np.concatenate([a, b]), p))
    assert cab >= ca + cb


@given(t=small_alphabet_text, seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_prefix_shift_invariance(t, seed):
    """Prepending k bytes shifts every match position by exactly k."""
    rng = np.random.RandomState(seed)
    m = int(rng.randint(1, 20))
    p = rng.randint(0, 4, size=m).astype(np.uint8)
    k = int(rng.randint(1, 8))
    prefix = rng.randint(4, 8, size=k).astype(np.uint8)  # disjoint alphabet
    base = np.asarray(epsm.find(t, p))
    shifted = np.asarray(epsm.find(np.concatenate([prefix, t]), p))
    np.testing.assert_array_equal(shifted[k:], base)


@given(
    t=small_alphabet_text,
    m=st.integers(2, 12),
    n_pat=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_multipattern_matches_individual(t, m, n_pat, seed):
    rng = np.random.RandomState(seed)
    pats = rng.randint(0, 4, size=(n_pat, m)).astype(np.uint8)
    if len(t) == 0:
        return
    stacked = np.asarray(find_multi(t, pats))
    for i in range(n_pat):
        np.testing.assert_array_equal(
            stacked[i], np.asarray(epsm.find(t, pats[i]))
        )


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_patternset_contains_any(seed):
    rng = np.random.RandomState(seed)
    t = rng.randint(0, 4, size=400).astype(np.uint8)
    present = t[13 : 13 + 6].copy()
    absent = np.full(6, 200, dtype=np.uint8)
    ps = PatternSet([absent, present])
    assert bool(ps.contains_any(t))
    ps2 = PatternSet([absent])
    assert not bool(ps2.contains_any(t))


@given(t=small_alphabet_text, algo=st.sampled_from(["epsma", "epsmb", "epsmc"]))
@settings(**SETTINGS)
def test_algorithms_agree(t, algo):
    """All three regimes produce identical masks on any input."""
    if len(t) < 20:
        return
    p = t[3:23].copy()  # m=20 valid for every regime (a/b generalize upward)
    np.testing.assert_array_equal(
        np.asarray(epsm.find(t, p, algo=algo)),
        np.asarray(epsm.find(t, p, algo="auto")),
    )
