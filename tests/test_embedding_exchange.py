"""All-to-all embedding exchange: exactness (incl. skew overflow fallback)
on a multi-device subprocess mesh."""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.dist.compat import make_mesh
from repro.dist.embedding_exchange import make_alltoall_lookup

mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.RandomState(0)
V, d, n = 4096, 16, 512
table = rng.randn(V, d).astype(np.float32)
lk = make_alltoall_lookup(mesh, "model", ("data",))

# uniform ids
ids = rng.randint(0, V, n).astype(np.int32)
got = np.asarray(lk(jnp.asarray(table), jnp.asarray(ids)))
assert np.array_equal(got, table[ids]), "uniform"

# zipf-skewed ids
ids = ((rng.zipf(1.3, n) - 1) % V).astype(np.int32)
got = np.asarray(lk(jnp.asarray(table), jnp.asarray(ids)))
assert np.array_equal(got, table[ids]), "zipf"

# adversarial: every id on one shard (forces the overflow fallback)
ids = np.full(n, 7, np.int32)
got = np.asarray(lk(jnp.asarray(table), jnp.asarray(ids)))
assert np.array_equal(got, table[ids]), "overflow"
print("EXCHANGE_OK")
"""


@pytest.mark.slow
def test_alltoall_exchange_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"}, cwd="/root/repo",
    )
    assert "EXCHANGE_OK" in res.stdout, res.stdout + res.stderr
