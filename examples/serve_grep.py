"""serve_grep — grep-as-a-service demo: in-process JSON-lines server,
concurrent clients, coalesced engine dispatches (repro.serve.query_plane,
DESIGN.md §15; operator guide in docs/serving.md).

    PYTHONPATH=src python examples/serve_grep.py [--queries 400]
                                                 [--clients 16]
                                                 [--size 500000]
                                                 [--trace service_trace.json]

Starts a :class:`GrepServer` on an ephemeral localhost port, loads two
synthetic corpora, and fires --queries grep queries from --clients
concurrent connections with skewed pattern popularity.  Every response is
checked bit-for-bit against a direct (uncoalesced) engine dispatch, then
the run prints QPS, request-latency p50/p99, and the coalescing ratio.
--trace exports the flight-recorder view of the run — the same artifact CI
validates with benchmarks/validate_trace.py.
"""

from __future__ import annotations

import argparse
import asyncio
import time

import numpy as np

from repro.core import engine
from repro.obs.recorder import Recorder
from repro.serve.query_plane import QueryPlane, ServiceConfig
from repro.serve.server import GrepClient, GrepServer

WORDS = [b"error", b"warn", b"timeout", b"retry", b"disk", b"net", b"oomkill"]


def make_corpus(size: int, seed: int) -> bytes:
    rng = np.random.RandomState(seed)
    text = rng.randint(97, 123, size=size).astype(np.uint8)
    for w in WORDS * max(1, size // 20_000):
        pos = int(rng.randint(0, size - 32))
        text[pos : pos + len(w)] = np.frombuffer(w, np.uint8)
    return text.tobytes()


def expected_counts(text: bytes, patterns) -> list:
    idx = engine.build_index(
        np.frombuffer(text, np.uint8)[None, :].copy(),
        np.array([len(text)], np.int32),
    )
    plans = engine.compile_patterns(list(patterns))
    out = np.asarray(engine.count_many(idx, plans))[0]
    return [int(c) for c in out[np.argsort(engine.plan_order(plans))]]


async def run(args) -> None:
    rng = np.random.RandomState(11)
    corpora = {f"logs{i}": make_corpus(args.size, i) for i in range(2)}
    rec = Recorder(enabled=bool(args.trace), fence=bool(args.trace))
    plane = QueryPlane(
        ServiceConfig(coalesce_ms=2.0, max_batch=64), recorder=rec
    )
    # skewed popularity: a few hot patterns dominate, like real query logs
    weights = 1.0 / np.arange(1, len(WORDS) + 1) ** 1.2
    weights /= weights.sum()

    async with GrepServer(plane) as (host, port):
        clients = [
            await GrepClient.connect(host, port) for _ in range(args.clients)
        ]
        for cid, text in corpora.items():
            await clients[0].add_corpus(cid, text)

        latencies: list = []
        checked = [0]

        async def worker(wi: int, n: int) -> None:
            wrng = np.random.RandomState(100 + wi)
            for _ in range(n):
                cid = f"logs{int(wrng.randint(0, 4) == 0)}"
                pats = [
                    WORDS[i]
                    for i in wrng.choice(
                        len(WORDS), size=1 + wrng.randint(0, 3),
                        replace=False, p=weights,
                    )
                ]
                t0 = time.perf_counter()
                resp = await clients[wi].query(cid, pats)
                latencies.append((time.perf_counter() - t0) * 1e3)
                assert resp["ok"], resp
                if checked[0] < 25:  # spot-check against direct dispatch
                    checked[0] += 1
                    want = expected_counts(corpora[cid], pats)
                    assert resp["counts"] == want, (pats, resp, want)

        per = -(-args.queries // args.clients)
        t0 = time.perf_counter()
        await asyncio.gather(*[worker(i, per) for i in range(args.clients)])
        wall = time.perf_counter() - t0

        stats = (await clients[0].stats())["stats"]
        for c in clients:
            await c.close()

    lat = np.sort(np.asarray(latencies))
    total = len(latencies)
    print(
        f"{total} queries from {args.clients} clients over "
        f"{len(corpora)} x {args.size / 1e6:.1f} MB corpora in {wall:.2f}s"
    )
    print(
        f"QPS {total / wall:,.0f}   p50 {lat[total // 2]:.2f} ms   "
        f"p99 {lat[min(total - 1, int(total * 0.99))]:.2f} ms"
    )
    print(
        f"dispatches: {stats['dispatches']} for {stats['requests']} requests"
        f" (coalescing ratio {stats['coalescing_ratio']:.1f}x, "
        f"{stats['result_cache_hits']} result-cache hits)"
    )
    assert checked[0] > 0 and stats["dispatches"] < stats["requests"]
    if args.trace:
        out = rec.export_trace(args.trace)
        print(f"trace written to {out} (validate: benchmarks/validate_trace.py)")
    print("ok — coalesced answers match direct engine dispatches")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=400)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--size", type=int, default=500_000)
    ap.add_argument("--trace", type=str, default=None)
    args = ap.parse_args()
    asyncio.run(run(args))


if __name__ == "__main__":
    main()
