"""Quickstart: EPSM packed string matching on the paper's three corpora.

    PYTHONPATH=src python examples/quickstart.py [--size 1000000]
"""

import argparse
import time

import numpy as np

import jax

from repro.core import baselines, epsm
from repro.core.multipattern import PatternSet, find_multi
from repro.data import corpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=1_000_000)
    args = ap.parse_args()

    print("=== EPSM quickstart ===")
    text = b"The quick brown fox jumps over the lazy dog. The dog sleeps."
    for pat in (b"The", b"dog", b"quick brown fox ", b"cat"):
        pos = epsm.positions(text, pat)
        print(f"  find({pat!r}) -> positions {list(pos)}")

    ps = PatternSet([b"fox", b"cat", b"dog"])
    print(f"  blocklist hit: {bool(ps.contains_any(text))}")

    print(f"\n=== throughput on {args.size/1e6:.1f}MB corpora ===")
    for name in ("genome", "protein", "english"):
        t = corpus.make_corpus(name, args.size, seed=0)
        row = [name]
        for m in (2, 8, 24):
            p = corpus.extract_patterns(t, m, 1, seed=1)[0]
            fn = jax.jit(lambda tt, pp: epsm.find(tt, pp))
            mask = fn(t, p)
            mask.block_until_ready()  # compile
            t0 = time.perf_counter()
            for _ in range(3):
                fn(t, p).block_until_ready()
            dt = (time.perf_counter() - t0) / 3
            occ = int(mask.sum())
            row.append(f"m={m}: {args.size/dt/1e9:.2f} GB/s ({occ} occ)")
        print(" ", " | ".join(row))

    print("\n=== cross-check vs scalar oracle ===")
    t = corpus.make_corpus("genome", 20_000, seed=2)
    p = corpus.extract_patterns(t, 16, 1, seed=3)[0]
    assert np.array_equal(np.asarray(epsm.find(t, p)), baselines.naive_np(t, p))
    print("  EPSM == oracle  OK")


if __name__ == "__main__":
    main()
