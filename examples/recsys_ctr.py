"""Train a reduced DIN CTR model on synthetic Zipf-skewed behavior data and
then score a candidate set through the retrieval path.

    PYTHONPATH=src python examples/recsys_ctr.py --steps 100
"""

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.data.recsys_data import make_batch
from repro.models import recsys as rs
from repro.train.loop import TrainConfig, train
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=256)
    args = ap.parse_args()

    cfg = reduced_config("din")
    params = rs.init_params(jax.random.key(0), cfg)

    def data():
        i = 0
        while True:
            yield make_batch(cfg, args.batch, seed=i)
            i += 1

    tc = TrainConfig(
        steps=args.steps, log_every=10, ckpt_every=10**9, ckpt_dir=None,
        opt=AdamWConfig(peak_lr=3e-3, warmup_steps=10, total_steps=args.steps,
                        weight_decay=0.0),
    )
    loss_fn = lambda p, b: rs.train_loss(p, cfg, b)
    params, _, hist = train(loss_fn, params, data(), tc)
    print(f"\nloss: {hist[0]:.4f} -> {hist[-1]:.4f}")

    # retrieval: one user vs 10k candidates
    user = {k: jnp.asarray(v[:1]) for k, v in make_batch(cfg, 4, seed=999).items()
            if k != "label"}
    cands = jnp.arange(10_000, dtype=jnp.int32) % cfg.item_vocab
    scores = rs.retrieval_scores(params, cfg, user, cands)
    top = np.argsort(np.asarray(scores))[::-1][:5]
    print(f"top-5 candidates: {list(top)}  scores {np.asarray(scores)[top].round(3)}")


if __name__ == "__main__":
    main()
