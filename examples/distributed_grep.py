"""Distributed grep: the paper's packed scan as a collective program.

Shards a corpus across 8 (simulated) devices, exchanges (m-1)-byte halos via
ppermute and psums occurrence counts — the 512-chip version of this is what
launch/dryrun.py lowers.  Must be its own process: device count locks at
first jax init.

    PYTHONPATH=src python examples/distributed_grep.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import baselines, distributed  # noqa: E402
from repro.data import corpus  # noqa: E402
from repro.dist.compat import make_mesh  # noqa: E402


def main():
    n = 8 * 1_000_000
    text = corpus.make_corpus("english", n, seed=0)
    patterns = [b"the ", b"people", b"government "]

    mesh = make_mesh((8,), ("data",))
    print(f"mesh: {mesh.devices.shape} over axis 'data'")
    find = distributed.make_distributed_find(mesh, "data")
    count = distributed.make_distributed_count(mesh, "data")

    for pat in patterns:
        p = np.frombuffer(pat, np.uint8)
        c = int(count(jnp.asarray(text), jnp.asarray(p)))
        t0 = time.perf_counter()
        for _ in range(3):
            count(jnp.asarray(text), jnp.asarray(p)).block_until_ready()
        dt = (time.perf_counter() - t0) / 3
        print(f"  {pat!r}: {c} occurrences   ({n/dt/1e9:.2f} GB/s across the mesh)")

    # exactness check incl. shard-boundary occurrences
    p = np.frombuffer(b"the ", np.uint8)
    got = np.asarray(find(jnp.asarray(text[:80000]), jnp.asarray(p)))
    # distributed_find requires the sharded length; rebuild a small mesh run
    want = baselines.naive_np(text[:80000], p)
    np.testing.assert_array_equal(got, want)
    print("  boundary-exactness vs oracle: OK")


if __name__ == "__main__":
    main()
