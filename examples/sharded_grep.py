"""sharded_grep — one logical corpus, S shards, exact counts (DESIGN.md §10).

    PYTHONPATH=src python examples/sharded_grep.py [--size 64000000]
        [--shards 0] [--chunk 4194304] [--processes 1]

Range-partitions a --size byte corpus into --shards shards (0 = one per
device; run under XLA_FLAGS=--xla_force_host_platform_device_count=8 to see
per-shard device placement on a laptop), plants query occurrences straddling
EVERY interior shard boundary at cycling phases, and scans with a
ShardedStreamScanner.  The queries contain a byte outside the corpus
alphabet, so every hit is a planted one and the count check is exact across
all shard seams.  Single-host results are also checked against the plain
1-shard StreamScanner wall clock for the scaling printout.

With --processes N the script respawns itself as an N-process
jax.distributed cluster (the CI weekly slow job runs N=2): each process
scans the shards ``i % N == process_index`` and counts merge through the
multihost psum; positions go through the ragged all-gather.
"""

from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys
import time

import numpy as np

ALPHA = 64  # corpus alphabet [0, 64); queries use byte 200


def make_queries():
    rng = np.random.RandomState(7)
    qs = []
    for m in (8, 16):
        q = rng.randint(0, ALPHA, size=m).astype(np.uint8)
        q[m // 2] = 200  # impossible in the corpus: hits == plants, exactly
        qs.append(q)
    return qs


def make_corpus(size: int, queries, boundaries):
    """The full corpus with each query planted straddling every interior
    shard boundary, queries and straddle phases cycling.  Returns (text,
    planted_counts, planted_positions)."""
    text = np.random.RandomState(1000).randint(0, ALPHA, size=size).astype(np.uint8)
    planted = [0] * len(queries)
    positions = [[] for _ in queries]
    last_end = -1
    for si, b in enumerate(boundaries):
        qi = si % len(queries)
        q = queries[qi]
        phase = 1 + (si % (len(q) - 1))  # 1..m-1: every seam relation occurs
        s = b - phase
        if s <= last_end or s < 0 or s + len(q) > size:
            continue
        text[s : s + len(q)] = q
        planted[qi] += 1
        positions[qi].append(s)
        last_end = s + len(q)
    return text, planted, [np.asarray(p, np.int64) for p in positions]


def spawn_cluster(args) -> int:
    """Respawn this script --processes times as a jax.distributed cluster."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    coordinator = f"127.0.0.1:{port}"
    procs = []
    for pid in range(args.processes):
        cmd = [
            sys.executable, os.path.abspath(__file__),
            "--size", str(args.size), "--shards", str(args.shards),
            "--chunk", str(args.chunk), "--processes", str(args.processes),
            "--process-id", str(pid), "--coordinator", coordinator,
        ]
        procs.append(subprocess.Popen(cmd, env=os.environ.copy()))
    rc = 0
    for p in procs:
        rc |= p.wait()
    if rc:
        raise SystemExit(f"cluster process failed (rc={rc})")
    print(f"cluster of {args.processes} processes: all exited cleanly")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=64_000_000)
    ap.add_argument("--chunk", type=int, default=1 << 22)
    ap.add_argument("--shards", type=int, default=0, help="0 = one per device")
    ap.add_argument("--processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=None, help=argparse.SUPPRESS)
    ap.add_argument("--coordinator", type=str, default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.processes > 1 and args.process_id is None:
        raise SystemExit(spawn_cluster(args))

    # joining a cluster must precede every other jax call
    from repro.launch.mesh import init_stream_cluster

    pid, nproc = init_stream_cluster(
        args.coordinator, args.processes, args.process_id
    )

    import jax

    from repro.core import engine
    from repro.core.shard_stream import ShardedStreamScanner
    from repro.core.stream import StreamScanner

    queries = make_queries()
    plans = engine.compile_patterns(queries)
    sc = ShardedStreamScanner(plans, args.shards or None, args.chunk)
    spec = sc.shard_spec(args.size)
    boundaries = [s for s, _ in spec.ranges[1:]]
    text, planted, planted_pos = make_corpus(args.size, queries, boundaries)
    if pid == 0:
        print(
            f"{args.size / 1e6:.0f} MB corpus, {spec.n_shards} shards over "
            f"{jax.device_count()} device(s) x {nproc} process(es); "
            f"{sum(planted)} occurrences planted across "
            f"{len(boundaries)} shard seams"
        )

    t0 = time.perf_counter()
    counts = sc.count_many(text)
    dt = time.perf_counter() - t0
    pos = ShardedStreamScanner(plans, args.shards or None, args.chunk).positions_many(text)

    order = sc.order  # engine rows are plan-concatenated
    ok = all(counts[r] == planted[order[r]] for r in range(len(counts)))
    ok &= all(
        np.array_equal(pos[r], planted_pos[order[r]]) for r in range(len(counts))
    )
    if pid == 0:
        print(f"sharded scan: {dt:.2f}s  ({args.size / dt / 1e9:.3f} GB/s)")
        if nproc == 1:
            t0 = time.perf_counter()
            base = StreamScanner(plans, args.chunk).count_many(text)
            dt1 = time.perf_counter() - t0
            assert np.array_equal(base, counts), "sharded != 1-shard stream"
            print(
                f"1-shard stream: {dt1:.2f}s  "
                f"(sharded speedup {dt1 / dt:.2f}x)"
            )
        for r in range(len(counts)):
            qi = order[r]
            print(
                f"query {qi} (m={len(queries[qi])}): {int(counts[r])} hits, "
                f"{planted[qi]} planted (seam-straddling)"
            )
        if not ok:
            raise SystemExit("FAIL: sharded counts/positions != planted")
        print("SHARDED_GREP_OK — exact across all shard seams")
    elif not ok:
        raise SystemExit(f"FAIL on process {pid}")


if __name__ == "__main__":
    main()
