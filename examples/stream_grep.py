"""stream_grep — constant-memory exact grep over a corpus that never fits
on device (repro.core.stream, DESIGN.md §9).

    PYTHONPATH=src python examples/stream_grep.py [--size 1000000000]
                                                  [--chunk 4194304]

Generates a --size byte corpus CHUNK BY CHUNK (the full text never exists
anywhere — not on device, not on host), plants query occurrences straddling
the scanner's window seams, and streams the whole thing through a
StreamScanner: device memory stays O(--chunk) while the count is exact.
The queries contain a byte outside the corpus alphabet, so every hit is a
planted one and the count check is exact, seams included.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import engine
from repro.core.stream import StreamScanner

GEN_CHUNK = 1 << 23  # host generation granularity (8 MiB)
ALPHA = 64           # corpus alphabet [0, 64); queries use byte 200


def make_queries():
    rng = np.random.RandomState(7)
    qs = []
    for m in (8, 16):
        q = rng.randint(0, ALPHA, size=m).astype(np.uint8)
        q[m // 2] = 200  # impossible in the corpus: hits == plants, exactly
        qs.append(q)
    return qs


def corpus(total: int, queries, seam_starts):
    """Yield uint8 chunks of a `total`-byte random corpus with each query
    planted at its seam-straddling start positions.  Plants that would cross
    a GENERATION chunk boundary are clipped to the next chunk's interior (a
    few positions shift; the planted count is returned via `planted`)."""
    planted = [0] * len(queries)
    pending = sorted(seam_starts, key=lambda sq: sq[0])
    base = 0
    i = 0
    while base < total:
        n = min(GEN_CHUNK, total - base)
        chunk = np.random.RandomState(1000 + i).randint(
            0, ALPHA, size=n
        ).astype(np.uint8)
        kept = []
        for start, qi in pending:
            q = queries[qi]
            if start < base:
                continue  # clipped away (crossed a generation boundary)
            if start + len(q) <= base + n:
                chunk[start - base : start - base + len(q)] = q
                planted[qi] += 1
            elif start < base + n:
                pass  # would straddle the generation seam: drop it
            else:
                kept.append((start, qi))
        pending = kept
        yield chunk
        base += n
        i += 1
    corpus.planted = planted  # smuggled out for the final check


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=1_000_000_000)
    ap.add_argument("--chunk", type=int, default=1 << 22)
    args = ap.parse_args()

    queries = make_queries()
    plans = engine.compile_patterns(queries)
    sc = StreamScanner(plans, args.chunk)
    step = sc.step_bytes

    # one plant straddling every 2nd window seam, alternating queries and
    # straddle phase so first-byte-left/last-byte-right seams both occur
    # (and both queries get planted even at the 16 MB CI smoke size)
    seam_starts = []
    w, si = 1, 0
    while w * step + 40 < args.size:
        qi = si % len(queries)
        phase = 1 + (si % (len(queries[qi]) - 1))
        seam_starts.append((w * step - phase, qi))
        w += 2
        si += 1

    t0 = time.perf_counter()
    counts = sc.count_many(corpus(args.size, queries, seam_starts))
    dt = time.perf_counter() - t0

    planted = corpus.planted
    order = sc.order  # engine rows are plan-concatenated
    ok = all(counts[r] == planted[order[r]] for r in range(len(counts)))
    gbps = args.size / dt / 1e9
    print(f"scanned {args.size / 1e6:.0f} MB in {dt:.2f}s  ({gbps:.3f} GB/s)")
    print(
        f"chunks: {sc.dispatch_count} x {sc.window_bytes} B window "
        f"(~{sc.device_bytes_per_chunk / 1e6:.1f} MB device working set; "
        f"resident index would need ~{9.5 * args.size / 1e9:.1f} GB)"
    )
    for r in range(len(counts)):
        qi = order[r]
        print(
            f"query {qi} (m={len(queries[qi])}): {int(counts[r])} hits, "
            f"{planted[qi]} planted (seam-straddling)"
        )
    if not ok:
        raise SystemExit("FAIL: streamed counts != planted occurrences")
    print("ok — exact across all window seams, O(chunk) device memory")


if __name__ == "__main__":
    main()
