"""End-to-end training driver: byte-level LM on the EPSM-filtered pipeline.

Trains a reduced smollm-135m-family model for a few hundred steps on CPU
(full 135M config selectable with --full on real hardware), with EPSM
blocklist filtering + fingerprint dedup in the data path, checkpointing,
straggler watchdog, and resume-on-restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses

import jax

from repro.configs import reduced_config, get_arch
from repro.data import corpus
from repro.data.pipeline import LMDataPipeline, VOCAB
from repro.dist.fault_tolerance import StepWatchdog
from repro.models import transformer as tf
from repro.train.loop import TrainConfig, train
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--full", action="store_true", help="full 135M config")
    args = ap.parse_args()

    if args.full:
        cfg = dataclasses.replace(get_arch("smollm-135m").make_config(), vocab=VOCAB)
    else:
        cfg = dataclasses.replace(
            reduced_config("smollm-135m"),
            vocab=VOCAB, d_model=128, n_layers=4, n_heads=4, n_kv_heads=2, d_ff=256,
            q_chunk=args.seq, kv_chunk=args.seq, ce_chunk=args.seq,
        )

    # the paper's technique in the data plane: blocklist + dedup
    blocklist = [b"FORBIDDEN", b"<secret>"]
    docs = corpus.documents("english", 10_000, doc_len=4096, seed=0)
    pipe = LMDataPipeline(
        docs, seq_len=args.seq, batch_size=args.batch,
        blocklist=blocklist, dedup=True,
    )

    params = tf.init_params(jax.random.key(0), cfg)
    n_params = sum(p.size for p in jax.tree_util.tree_leaves(params))
    print(f"model: {n_params/1e6:.1f}M params  vocab={cfg.vocab}")

    tc = TrainConfig(
        steps=args.steps,
        log_every=10,
        ckpt_every=max(args.steps // 4, 25),
        ckpt_dir=args.ckpt_dir,
        opt=AdamWConfig(peak_lr=3e-3, warmup_steps=20, total_steps=args.steps),
    )
    wd = StepWatchdog(factor=5.0, policy="log")
    loss_fn = lambda p, b: tf.train_loss(p, cfg, b)
    params, _, hist = train(loss_fn, params, pipe, tc, watchdog=wd)
    print(f"\nfinal loss {hist[-1]:.4f} (start {hist[0]:.4f})")
    print(f"pipeline stats: {pipe.stats}")
    if wd.events:
        print(f"straggler events: {len(wd.events)}")


if __name__ == "__main__":
    main()
