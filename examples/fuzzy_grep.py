"""fuzzy_grep — typo-tolerant multi-pattern search with repro.approx.

    PYTHONPATH=src python examples/fuzzy_grep.py [--k 1] [--size 200000]

Plants corrupted copies of a query into a synthetic corpus and contrasts the
exact packed matcher (misses them) with the k-mismatch engine (finds them):
the fuzzy-grep / DNA-read-filter / typo-blocklist workload in ~60 lines.
One engine dispatch answers all queries x all budgets' worth of texts; see
DESIGN.md §8 for the packed counting filter + relaxed fingerprint gate.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.approx import kmismatch_naive
from repro.core import engine
from repro.data import corpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--size", type=int, default=200_000)
    args = ap.parse_args()

    rng = np.random.RandomState(42)
    text = np.array(corpus.make_corpus("english", args.size, seed=1))
    query = text[5_000:5_012].copy()  # m = 12 window from the corpus itself

    # plant 3 corrupted copies: 1 typo, args.k typos, args.k + 1 typos
    sites = {}
    for i, typos in enumerate((1, args.k, args.k + 1)):
        site = 20_000 + 30_000 * i
        w = query.copy()
        for j in rng.choice(len(w), size=typos, replace=False):
            w[j] ^= rng.randint(1, 256)
        text[site : site + len(w)] = w
        sites[site] = typos

    idx = engine.build_index(text)
    for k in (0, args.k):
        plans = engine.compile_patterns([query], k=k)
        mask = np.asarray(engine.match_many_jit(idx, plans, k=k))[0, 0]
        hits = np.nonzero(mask)[0]
        naive = np.nonzero(kmismatch_naive(text, query, k))[0]
        assert np.array_equal(hits, naive), "engine/naive divergence"
        planted = [s for s in sites if s in set(hits.tolist())]
        print(
            f"k={k}: {len(hits)} hit(s) at {hits.tolist()[:8]} "
            f"(planted sites found: {planted})"
        )
        for s, typos in sites.items():
            status = "FOUND" if s in set(hits.tolist()) else "missed"
            print(f"    site {s} ({typos} typo(s)): {status}")
    print("ok")


if __name__ == "__main__":
    main()
