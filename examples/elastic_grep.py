"""elastic_grep — exact scans over a flaky object store (DESIGN.md §12).

    PYTHONPATH=src python examples/elastic_grep.py [--size 8000000]
        [--shards 0] [--chunk 4194304] [--fault-rate 0.05] [--seed 0]
        [--trace TRACE.json]

The whole elastic fabric in one run: the corpus lives behind a
FakeObjectStore (a range-GET "RPC" with injected faults), a
RemoteRangeReader fetches it in prefetched parts with per-part timeout and
classified backoff retry, and a ShardedStreamScanner with work stealing
scans it — shard crashes injected inside the retry scope, straggling shards
shedding trailing ranges to idle lanes.  Counts must equal the clean
single-host StreamScanner bit-for-bit despite every injected fault.

Then the degraded path: the faults are made PERMANENT, and the same scan
with on_exhausted="partial" returns a PartialScanResult naming exactly
which byte ranges were lost instead of raising.

Run under XLA_FLAGS=--xla_force_host_platform_device_count=8 (the CI chaos
job does) to see the lanes spread over devices.  With --trace PATH the run
attaches a flight recorder (repro.obs, DESIGN.md §13) and exports a
Chrome/Perfetto trace: per-lane span tracks, one retry event per injected
fault, every steal/shed with its exact byte range — open it in
https://ui.perfetto.dev.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

ALPHA = 64  # corpus alphabet [0, 64); queries use byte 200


def make_queries():
    rng = np.random.RandomState(7)
    qs = []
    for m in (8, 16):
        q = rng.randint(0, ALPHA, size=m).astype(np.uint8)
        q[m // 2] = 200  # impossible in the corpus: hits == plants, exactly
        qs.append(q)
    return qs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=8_000_000)
    ap.add_argument("--chunk", type=int, default=1 << 22)
    ap.add_argument("--shards", type=int, default=0, help="0 = one per device")
    ap.add_argument("--fault-rate", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", type=str, default=None,
                    help="export a Perfetto trace of the faulty scan here")
    args = ap.parse_args()

    import jax

    from repro.core import engine
    from repro.core.remote_source import FakeObjectStore
    from repro.core.shard_stream import PartialScanResult, ShardedStreamScanner
    from repro.core.stream import StreamScanner
    from repro.dist.fault_injection import FaultPlan
    from repro.dist.fault_tolerance import BackoffPolicy
    from repro.obs import Recorder

    queries = make_queries()
    plans = engine.compile_patterns(queries)

    text = np.random.RandomState(1000).randint(
        0, ALPHA, size=args.size
    ).astype(np.uint8)
    rng = np.random.RandomState(3)
    planted = [0] * len(queries)
    for _ in range(200):  # scatter plants so every shard owns some
        qi = rng.randint(len(queries))
        q = queries[qi]
        s = rng.randint(0, args.size - len(q))
        if (text[s : s + len(q)] == 200).any() or 200 in q[:0]:
            continue
        if (text[max(0, s - 16) : s + len(q) + 16] == 200).any():
            continue  # keep plants disjoint from each other
        text[s : s + len(q)] = q
        planted[qi] += 1

    want = StreamScanner(plans, args.chunk).count_many(text)

    r = args.fault_rate
    plan = FaultPlan(
        args.seed, read_error_rate=r, truncate_rate=r, crash_rate=r,
        attempts_per_fault=1,
    )
    rec = Recorder(enabled=True, fence=False) if args.trace else None
    store = FakeObjectStore(text, plan=plan)
    reader = store.reader(part_bytes=1 << 20, prefetch=3, retries=4,
                          timeout_s=30.0, recorder=rec)
    sc = ShardedStreamScanner(
        plans, args.shards or None, args.chunk, max_retries=16,
        fault_plan=plan, steal=True, min_steal_bytes=1 << 16,
        backoff=BackoffPolicy(base_s=0.001, seed=args.seed),
        recorder=rec,
    )
    print(
        f"{args.size / 1e6:.0f} MB corpus behind a faulty object store "
        f"({r:.0%} read errors + truncations + shard crashes per site), "
        f"{sc.n_shards} shards over {jax.device_count()} device(s), "
        f"work stealing ON"
    )
    t0 = time.perf_counter()
    counts = sc.count_many(reader)
    dt = time.perf_counter() - t0
    faults = plan.counts_by_action()
    print(
        f"elastic scan: {dt:.2f}s ({args.size / dt / 1e9:.3f} GB/s)  "
        f"injected={faults}  shard_retries={len(sc.events)}  "
        f"part_retries={reader.stats.retries}  steals={len(sc.steal_events)}"
    )
    if not np.array_equal(counts, want):
        raise SystemExit("FAIL: recovered counts != clean oracle")
    for qi, n in zip(sc.order, counts):
        print(f"query {qi} (m={len(queries[qi])}): {int(n)} hits "
              f"({planted[qi]} planted)")
    print("recovered counts are bit-identical to the clean scan")

    if rec is not None:
        rec.export_trace(args.trace)
        evs = {k: len(rec.events_named(k))
               for k in ("fault", "retry", "steal", "shed", "range_done")}
        done = sorted(
            (e["start"], e["stop"]) for e in rec.events_named("range_done")
        )
        covered = sum(e - s for s, e in done)
        print(
            f"trace -> {args.trace}  events: "
            + "  ".join(f"{k}={v}" for k, v in evs.items() if v)
            + f"  range_done coverage: {covered}/{args.size} bytes"
        )

    # -- graceful degradation: permanent faults, partial result -------------
    perm = FaultPlan(args.seed + 1, crash_rate=0.3, attempts_per_fault=None)
    sc2 = ShardedStreamScanner(
        plans, args.shards or None, args.chunk, max_retries=1,
        fault_plan=perm, on_exhausted="partial",
    )
    res = sc2.count_many(text)
    assert isinstance(res, PartialScanResult)
    print(
        f"permanent crashes + on_exhausted='partial': "
        f"covered {res.coverage_fraction():.0%} "
        f"({len(res.missing)} missing range(s): "
        f"{[(int(s), int(e)) for s, e in res.missing]})"
    )
    if res.complete:
        print("  (this seed killed no shard — rerun with another --seed)")
    print("ELASTIC_GREP_OK — exact under faults, explicit when degraded")


if __name__ == "__main__":
    main()
